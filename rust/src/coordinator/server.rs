//! The threaded edge-server event loop (Sec. 3.1 workflow, Fig. 2a).
//!
//! One server thread owns the state pool, the decision maker and the
//! offload executor, and speaks to its UEs through a pluggable
//! [`ServerTransport`] — in-process channels ([`EdgeServer::spawn`]) or
//! real TCP sockets ([`EdgeServer::spawn_on`] with
//! [`crate::transport::tcp::TcpServerTransport`]). Per tick the server:
//!
//! 1. drains uplink frames (state reports, offloaded payloads, goodbyes)
//!    — at most `drain_limit` per tick, so an offload flood cannot starve
//!    decision broadcasts. Malformed offloads (a feature payload with no
//!    calibration) are NACKed at admission, before they cost a worker;
//! 2. if a decision interval elapsed, assembles the state pool and
//!    broadcasts the next [`FrameDecision`];
//! 3. routes offloads to the [`OffloadExecutor`] worker pool (raw inputs
//!    through the dynamic batcher) and drains completions back onto the
//!    owning UE's downlink. The server thread itself never runs model
//!    math unless `exec.workers` is 0 (the inline-serial baseline).
//!
//! std threads + mpsc stand in for tokio (offline build — see DESIGN.md);
//! the loop structure is identical to an async reactor with a timer.

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::decision::DecisionMaker;
use super::executor::{Completion, ExecutorConfig, ExecutorStats, OffloadCompute, OffloadExecutor};
use super::learner::TelemetryFrame;
use super::offload_cache::{CacheStats, OffloadCache};
use super::protocol::{Downlink, UeStateReport, Uplink};
use super::state_pool::StatePool;
use crate::env::mdp::MultiAgentEnv;
use crate::env::{Action, HybridAction};
use crate::transport::channel::{self, ChannelServerTransport};
use crate::transport::{ServerTransport, TransportError};

/// Server-side counters (exposed after shutdown).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    pub frames: usize,
    pub reports: usize,
    pub offloads_served: usize,
    pub raw_offloads: usize,
    pub feature_offloads: usize,
    pub offload_errors: usize,
    pub edge_compute_s: f64,
    /// Policy hot-swaps applied between decision frames (see
    /// [`super::decision::PolicyHandle`]).
    pub policy_swaps: usize,
    /// Downlink frames the transport dropped under backpressure (bounded
    /// queue or write buffer full — see [`ServerTransport::take_drops`]).
    /// Surfaced here and in `BENCH_load.json` so decision frames lost to
    /// slow consumers are counted, never silent.
    pub downlink_drops: usize,
    /// Telemetry frames dropped because the bounded learner feed was full
    /// (the learner was mid-update and not draining). Serving deliberately
    /// sheds telemetry rather than stall — but the shed must be counted,
    /// not a silent `let _ =`.
    pub telemetry_drops: usize,
    /// Executor counters (queue depth / queue wait / batch occupancy);
    /// default-zero when serving ran inline on the server thread.
    pub exec: ExecutorStats,
    /// Content-addressed offload cache counters (hits / misses / bytes
    /// saved / evictions); default-zero when the cache is off
    /// (`ServerConfig::offload_cache` = 0).
    pub cache: CacheStats,
}

/// Handle to a running edge server on the in-process channel transport.
pub struct EdgeServer {
    pub uplink: SyncSender<Uplink>,
    handle: EdgeServerHandle,
}

/// Join handle over the server thread; also what [`EdgeServer::spawn_on`]
/// returns for external transports (e.g. TCP), where there is no
/// in-process uplink sender to expose.
pub struct EdgeServerHandle {
    handle: Option<JoinHandle<ServerStats>>,
}

impl EdgeServerHandle {
    /// Wrap a raw server-loop thread handle (how [`super::shard`] exposes
    /// each shard's loop under the same join API).
    pub(crate) fn from_join(handle: JoinHandle<ServerStats>) -> EdgeServerHandle {
        EdgeServerHandle {
            handle: Some(handle),
        }
    }

    /// Wait for the server loop to exit and collect its stats.
    pub fn join(mut self) -> ServerStats {
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

/// Everything the server thread needs.
pub struct ServerConfig {
    pub n_ues: usize,
    /// Real-time decision interval (scaled-down T0 for the demo loop).
    pub decision_interval: Duration,
    /// Stop after this many decision frames even if UEs linger.
    pub max_frames: usize,
    /// Max uplink messages drained per tick: bounds how long a sustained
    /// offload flood can defer the decision-broadcast check.
    pub drain_limit: usize,
    /// Offload executor knobs (worker count + raw-batching policy).
    pub exec: ExecutorConfig,
    /// When set, every decision broadcast also exports one
    /// [`TelemetryFrame`] (assembled state + issued actions) on this
    /// **bounded** channel (`std::sync::mpsc::sync_channel`) — the feed
    /// the online [`super::learner`] consumes. The export is `try_send`:
    /// a full queue (learner slower than the decision rate) drops the
    /// frame and a vanished consumer is ignored, so serving never stalls
    /// — and never grows memory — on telemetry.
    pub telemetry: Option<SyncSender<TelemetryFrame>>,
    /// Broadcast each UE a slimmed [`FrameDecision`] holding only its own
    /// action (index 0) instead of the full joint action vector. Opt-in:
    /// the default full broadcast is what [`drive_env_ues`] and the
    /// existing examples expect; sharded fleet serving turns this on so a
    /// 10k-UE broadcast is O(n) bytes, not O(n²).
    pub per_ue_decisions: bool,
    /// Exit the loop once every UE has said (or been synthesized a)
    /// `Goodbye`. Default true — the historical behavior. Fleet serving
    /// under reconnect churn sets this false: an instant where all UEs
    /// happen to be between sessions must not stop the shard; the loop
    /// then ends via `max_frames` or transport closure.
    pub exit_when_empty: bool,
    /// Let the periodic decision tick fire once *any* fresh report is
    /// pooled, instead of waiting for a full assembly. Default false (the
    /// paper's synchronous frame). Fleet serving sets this true: with
    /// thousands of churning UEs the pool is essentially never complete,
    /// and stale slots are served their last-known state.
    pub decide_on_partial: bool,
    /// Capacity (entries) of the content-addressed offload result cache
    /// consulted before the executor: identical payloads under the same
    /// (partition, calibration) key are served from memory, bit-identical
    /// to a recompute. 0 disables the cache (the historical behavior).
    /// Defaults to `MACCI_OFFLOAD_CACHE` (see [`crate::util::config`]).
    pub offload_cache: usize,
}

impl ServerConfig {
    pub fn new(n_ues: usize, decision_interval: Duration, max_frames: usize) -> ServerConfig {
        ServerConfig {
            n_ues,
            decision_interval,
            max_frames,
            drain_limit: 128,
            exec: ExecutorConfig::default(),
            telemetry: None,
            per_ue_decisions: false,
            exit_when_empty: true,
            decide_on_partial: false,
            offload_cache: crate::util::config::offload_cache(),
        }
    }
}

impl EdgeServer {
    /// Spawn the server thread on the in-process channel transport.
    /// `downlinks[ue_id]` receives that UE's decisions and inference
    /// results. `compute` may be `None` for a decision-only server (pure
    /// scheduling, no model serving).
    pub fn spawn(
        cfg: ServerConfig,
        mut pool: StatePool,
        mut decisions: DecisionMaker,
        compute: Option<Arc<dyn OffloadCompute>>,
    ) -> Result<(EdgeServer, Vec<Receiver<Downlink>>)> {
        let (uplink_tx, uplink_rx) = sync_channel::<Uplink>(channel::UPLINK_QUEUE);
        let mut downlink_txs: Vec<SyncSender<Downlink>> = Vec::with_capacity(cfg.n_ues);
        let mut downlink_rxs: Vec<Receiver<Downlink>> = Vec::with_capacity(cfg.n_ues);
        for _ in 0..cfg.n_ues {
            let (tx, rx) = sync_channel(channel::DOWNLINK_QUEUE);
            downlink_txs.push(tx);
            downlink_rxs.push(rx);
        }
        let mut transport = ChannelServerTransport::from_parts(uplink_rx, downlink_txs);

        let handle = std::thread::Builder::new()
            .name("edge-server".into())
            .spawn(move || {
                server_loop(cfg, &mut transport, &mut pool, &mut decisions, compute)
            })?;

        Ok((
            EdgeServer {
                uplink: uplink_tx,
                handle: EdgeServerHandle {
                    handle: Some(handle),
                },
            },
            downlink_rxs,
        ))
    }

    /// Spawn the server thread on an arbitrary [`ServerTransport`] —
    /// this is how remote UEs are served over TCP (see the
    /// `remote_serving` example and README §Remote serving).
    pub fn spawn_on(
        cfg: ServerConfig,
        mut pool: StatePool,
        mut decisions: DecisionMaker,
        compute: Option<Arc<dyn OffloadCompute>>,
        mut transport: impl ServerTransport + 'static,
    ) -> Result<EdgeServerHandle> {
        let handle = std::thread::Builder::new()
            .name("edge-server".into())
            .spawn(move || {
                server_loop(cfg, &mut transport, &mut pool, &mut decisions, compute)
            })?;
        Ok(EdgeServerHandle {
            handle: Some(handle),
        })
    }

    /// Wait for the server loop to exit and collect its stats.
    pub fn join(self) -> ServerStats {
        self.handle.join()
    }
}

/// Send a finished offload to its owner — a `Result` on success, an
/// `Error` NACK on failure (the owner must never wait forever). Successes
/// also settle the cache's pending note for this task, so identical
/// future payloads are served from memory.
fn route_completion(
    c: Completion,
    transport: &mut dyn ServerTransport,
    stats: &mut ServerStats,
    cache: &mut OffloadCache,
) {
    match c.outcome {
        Ok(result) => {
            stats.offloads_served += 1;
            stats.edge_compute_s += result.edge_latency_s;
            cache.complete(c.ue_id, c.task_id, Some(&result));
            let ue_id = result.ue_id;
            transport.send_to(ue_id, Downlink::Result(result));
        }
        Err(e) => {
            stats.offload_errors += 1;
            cache.complete(c.ue_id, c.task_id, None);
            log::error!("offload task {} from UE {}: {e:#}", c.task_id, c.ue_id);
            transport.send_to(
                c.ue_id,
                Downlink::Error {
                    task_id: c.task_id,
                    error: format!("{e:#}"),
                },
            );
        }
    }
}

pub(crate) fn server_loop(
    cfg: ServerConfig,
    transport: &mut dyn ServerTransport,
    pool: &mut StatePool,
    decisions: &mut DecisionMaker,
    compute: Option<Arc<dyn OffloadCompute>>,
) -> ServerStats {
    let mut stats = ServerStats::default();
    let mut alive: HashMap<usize, bool> = (0..cfg.n_ues).map(|i| (i, true)).collect();
    let mut cache = OffloadCache::new(cfg.offload_cache);
    // reused (ue, action-index) target scratch for the decision fan-out
    let mut bcast_targets: Vec<(usize, usize)> = Vec::with_capacity(cfg.n_ues);
    let mut last_decision = Instant::now();
    // issue an initial decision as soon as the first full pool assembles
    let mut first_decision_done = false;
    // set when the transport reports closure: no client can ever speak again
    let mut uplink_disconnected = false;

    // with workers, the server thread only routes; model math runs in the
    // pool (workers == 0 keeps the inline-serial baseline)
    let mut executor = match (&compute, cfg.exec.workers) {
        (Some(c), w) if w > 0 => match OffloadExecutor::start(c.clone(), cfg.exec) {
            Ok(ex) => Some(ex),
            Err(e) => {
                log::error!("offload executor failed to start, serving inline: {e:#}");
                None
            }
        },
        _ => None,
    };

    loop {
        // -- drain the uplink (bounded per tick) --
        let mut drained = 0usize;
        while drained < cfg.drain_limit.max(1) {
            match transport.try_recv() {
                Ok(Some(Uplink::Report(r))) => {
                    drained += 1;
                    stats.reports += 1;
                    // a report re-enters the UE into the system: a remote
                    // client that dropped (synthesized Goodbye) and came
                    // back resumes receiving decision broadcasts
                    if r.ue_id < cfg.n_ues {
                        alive.insert(r.ue_id, true);
                    }
                    pool.ingest(r);
                }
                Ok(Some(Uplink::Offload(req))) => {
                    drained += 1;
                    // admission check: a feature offload without its
                    // (lo, hi) calibration can never be decoded — NACK
                    // now instead of failing later on a worker
                    if req.b >= 1 && req.calibration.is_none() {
                        stats.offload_errors += 1;
                        transport.send_to(
                            req.ue_id,
                            Downlink::Error {
                                task_id: req.task_id,
                                error: format!(
                                    "feature offload (b = {}) without calibration \
                                     rejected at admission",
                                    req.b
                                ),
                            },
                        );
                        continue;
                    }
                    let Some(cmp) = compute.as_ref() else {
                        // decision-only server: NACK rather than silently
                        // dropping — the owner must never wait forever
                        stats.offload_errors += 1;
                        transport.send_to(
                            req.ue_id,
                            Downlink::Error {
                                task_id: req.task_id,
                                error: "server is decision-only (no serving compute)".into(),
                            },
                        );
                        continue;
                    };
                    if req.b == 0 {
                        stats.raw_offloads += 1;
                    } else {
                        stats.feature_offloads += 1;
                    }
                    // content-addressed cache: an identical payload under
                    // the same (partition, calibration) key skips the
                    // executor entirely — the stored result is
                    // bit-identical to a recompute
                    if let Some(hit) = cache.lookup(&req) {
                        stats.offloads_served += 1;
                        transport.send_to(req.ue_id, Downlink::Result(hit));
                        continue;
                    }
                    cache.note_pending(&req);
                    match executor.as_mut() {
                        Some(ex) => ex.submit(req),
                        None => {
                            let done = Completion {
                                ue_id: req.ue_id,
                                task_id: req.task_id,
                                outcome: cmp.serve(&req),
                                queue_wait: Duration::ZERO,
                                batch_size: 1,
                            };
                            route_completion(done, transport, &mut stats, &mut cache);
                            // inline serving runs model math inside this
                            // loop: bound the drain by time too, not just
                            // message count, so a flood cannot defer the
                            // decision tick
                            if last_decision.elapsed() >= cfg.decision_interval {
                                break;
                            }
                        }
                    }
                }
                Ok(Some(Uplink::Goodbye { ue_id })) => {
                    drained += 1;
                    alive.insert(ue_id, false);
                }
                Ok(None) => break,
                Err(TransportError::Closed) => {
                    // no client can ever speak again: treat full closure
                    // as shutdown instead of busy-spinning to max_frames
                    uplink_disconnected = true;
                    break;
                }
                Err(e) => {
                    // transports validate frames internally; anything
                    // else reaching the loop is terminal too
                    log::error!("uplink transport failed: {e}");
                    uplink_disconnected = true;
                    break;
                }
            }
        }
        let mut worked = drained > 0;

        // -- pump the batcher, route finished offloads --
        if let Some(ex) = executor.as_mut() {
            ex.pump(Instant::now());
            for c in ex.try_completions() {
                worked = true;
                route_completion(c, transport, &mut stats, &mut cache);
            }
        }

        // -- all UEs done or gone? --
        if uplink_disconnected {
            log::debug!("uplink fully disconnected — shutting down");
            break;
        }
        if cfg.exit_when_empty && alive.values().all(|&a| !a) {
            break;
        }
        if stats.frames >= cfg.max_frames {
            break;
        }

        // -- decision tick --
        let due = last_decision.elapsed() >= cfg.decision_interval;
        let partial_ready = cfg.decide_on_partial && pool.fresh_count() > 0;
        let ready = pool.complete() || first_decision_done || partial_ready;
        if (due && ready) || (!first_decision_done && pool.complete()) {
            let state = pool.assemble();
            match decisions.next_decision(&state) {
                Ok(d) => {
                    stats.frames += 1;
                    first_decision_done = true;
                    // fan out through the transport's broadcast: every
                    // live UE is a target addressing its own action row
                    // (channel/tcp loop per UE; the reactor encodes the
                    // shared body once for the whole set)
                    bcast_targets.clear();
                    bcast_targets
                        .extend(alive.iter().filter(|&(_, &a)| a).map(|(&ue, _)| (ue, ue)));
                    transport.broadcast_decision(&d, &bcast_targets, cfg.per_ue_decisions);
                    // export serving telemetry for the online learner —
                    // non-blocking: a full queue (learner mid-update)
                    // drops the frame and is counted; a gone consumer is
                    // ignored (shutdown, not backpressure)
                    if let Some(tx) = &cfg.telemetry {
                        if let Err(TrySendError::Full(_)) = tx.try_send(TelemetryFrame {
                            frame: d.frame,
                            state,
                            actions: d.actions,
                        }) {
                            stats.telemetry_drops += 1;
                        }
                    }
                }
                Err(e) => log::error!("decision failed: {e:#}"),
            }
            last_decision = Instant::now();
        }

        if !worked {
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    // graceful drain: every accepted offload still completes and reaches
    // its owner before the shutdown frames go out
    if let Some(ex) = executor.take() {
        let (rest, xstats) = ex.drain_shutdown();
        for c in rest {
            route_completion(c, transport, &mut stats, &mut cache);
        }
        stats.exec = xstats;
    }

    for ue_id in 0..cfg.n_ues {
        transport.send_to(ue_id, Downlink::Shutdown);
    }
    stats.policy_swaps = decisions.swaps_applied();
    stats.downlink_drops = transport.take_drops();
    stats.cache = cache.stats();
    stats
}

/// Drive simulated UEs from the analytic env against a server spawned on
/// the in-process channel transport: each frame reports every UE's state,
/// awaits the decision broadcast on every downlink, hands the broadcast
/// joint action to `on_frame`, then executes it on the env (clamped into
/// the env's action space; episodes reset on completion). Returns the
/// per-UE received-decision counts after `frames` frames — equal to the
/// server's broadcast count exactly when no broadcast was missed. Shared
/// by `macci serve --policy` and the `policy_lifecycle` example.
pub fn drive_env_ues(
    uplink: &SyncSender<Uplink>,
    downlinks: &[Receiver<Downlink>],
    env: &mut MultiAgentEnv,
    frames: usize,
    mut on_frame: impl FnMut(usize, &[HybridAction]),
) -> Result<Vec<usize>> {
    let n = downlinks.len();
    let mut received = vec![0usize; n];
    for frame in 0..frames {
        for ue in env.ues() {
            let _ = uplink.send(Uplink::Report(UeStateReport {
                ue_id: ue.id,
                tasks_left: ue.tasks_left,
                compute_left_s: ue.remaining_compute_s(),
                offload_left_bits: ue.remaining_offload_bits(),
                distance_m: ue.distance,
            }));
        }
        let mut actions: Action = vec![HybridAction::new(0, 0, 0.0, env.cfg.p_max); n];
        let slots = actions.iter_mut().zip(received.iter_mut());
        for ((ue, rx), (slot, count)) in downlinks.iter().enumerate().zip(slots) {
            loop {
                match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(Downlink::Decision(d)) => {
                        anyhow::ensure!(
                            d.actions.len() == n,
                            "decision has {} actions for {n} UEs",
                            d.actions.len()
                        );
                        if let Some(a) = d.actions.get(ue) {
                            *slot = *a;
                        }
                        *count += 1;
                        break;
                    }
                    Ok(Downlink::Shutdown) => anyhow::bail!("server shut down mid-run"),
                    Ok(_) => continue,
                    Err(e) => anyhow::bail!("awaiting decision for UE {ue}: {e}"),
                }
            }
        }
        on_frame(frame, &actions);
        let clamp: Action = actions
            .iter()
            .map(|a| {
                HybridAction::new(
                    a.b.min(env.profile.n_choices - 1),
                    a.c.min(env.cfg.n_channels - 1),
                    a.p_raw,
                    env.cfg.p_max,
                )
            })
            .collect();
        if env.step(&clamp).done {
            env.reset();
        }
    }
    Ok(received)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::decision::StaticDecision;
    use crate::coordinator::protocol::{OffloadRequest, UeStateReport};
    use crate::coordinator::state_pool::StateNorm;
    use crate::env::HybridAction;

    #[test]
    fn decision_only_server_round() {
        let n = 3;
        let pool = StatePool::new(
            n,
            StateNorm {
                lambda_tasks: 10.0,
                frame_s: 0.5,
                max_bits: 1e6,
                d_max: 100.0,
            },
        );
        let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![
            HybridAction::new(5, 0, 0.0, 1.0);
            n
        ])));
        let cfg = ServerConfig::new(n, Duration::from_millis(5), 3);
        let (server, downlinks) = EdgeServer::spawn(cfg, pool, dm, None).unwrap();

        // all UEs report, then await decisions
        for ue in 0..n {
            server
                .uplink
                .send(Uplink::Report(UeStateReport {
                    ue_id: ue,
                    tasks_left: 5,
                    compute_left_s: 0.0,
                    offload_left_bits: 0.0,
                    distance_m: 40.0,
                }))
                .unwrap();
        }
        let mut got = 0;
        for rx in &downlinks {
            if let Ok(Downlink::Decision(d)) = rx.recv_timeout(Duration::from_secs(2)) {
                assert_eq!(d.actions.len(), n);
                got += 1;
            }
        }
        assert_eq!(got, n, "every UE receives the broadcast");
        for ue in 0..n {
            server.uplink.send(Uplink::Goodbye { ue_id: ue }).unwrap();
        }
        let stats = server.join();
        assert!(stats.frames >= 1);
        assert_eq!(stats.reports, n);
    }

    #[test]
    fn decision_only_server_nacks_offloads() {
        let pool = StatePool::new(
            1,
            StateNorm {
                lambda_tasks: 10.0,
                frame_s: 0.5,
                max_bits: 1e6,
                d_max: 100.0,
            },
        );
        let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![
            HybridAction::new(5, 0, 0.0, 1.0);
            1
        ])));
        let cfg = ServerConfig::new(1, Duration::from_millis(5), usize::MAX);
        let (server, downlinks) = EdgeServer::spawn(cfg, pool, dm, None).unwrap();
        server
            .uplink
            .send(Uplink::Offload(OffloadRequest {
                ue_id: 0,
                task_id: 7,
                b: 0,
                payload: Vec::new(),
                calibration: None,
            }))
            .unwrap();
        match downlinks[0].recv_timeout(Duration::from_secs(2)).unwrap() {
            Downlink::Error { task_id, error } => {
                assert_eq!(task_id, 7);
                assert!(error.contains("decision-only"), "unexpected NACK: {error}");
            }
            other => panic!("expected a NACK, got {other:?}"),
        }
        server.uplink.send(Uplink::Goodbye { ue_id: 0 }).unwrap();
        let stats = server.join();
        assert_eq!(stats.offload_errors, 1);
        assert_eq!(stats.raw_offloads, 0, "dropped offloads are not counted as accepted");
    }

    /// The admission check: a feature offload with no calibration NACKs
    /// immediately — it never reaches the compute (which would only fail
    /// it later, on a worker thread).
    #[test]
    fn calibrationless_feature_offload_nacks_at_admission() {
        let pool = StatePool::new(
            1,
            StateNorm {
                lambda_tasks: 10.0,
                frame_s: 0.5,
                max_bits: 1e6,
                d_max: 100.0,
            },
        );
        let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![
            HybridAction::new(5, 0, 0.0, 1.0);
            1
        ])));
        let cfg = ServerConfig::new(1, Duration::from_millis(5), usize::MAX);
        let compute = Arc::new(crate::coordinator::executor::SyntheticCompute::new(
            Duration::from_micros(10),
        ));
        let (server, downlinks) =
            EdgeServer::spawn(cfg, pool, dm, Some(compute as Arc<dyn OffloadCompute>)).unwrap();
        server
            .uplink
            .send(Uplink::Offload(OffloadRequest {
                ue_id: 0,
                task_id: 3,
                b: 2,
                payload: vec![1, 2, 3],
                calibration: None,
            }))
            .unwrap();
        match downlinks[0].recv_timeout(Duration::from_secs(2)).unwrap() {
            Downlink::Error { task_id, error } => {
                assert_eq!(task_id, 3);
                assert!(error.contains("calibration"), "unexpected NACK: {error}");
                assert!(error.contains("admission"), "unexpected NACK: {error}");
            }
            other => panic!("expected a NACK, got {other:?}"),
        }
        server.uplink.send(Uplink::Goodbye { ue_id: 0 }).unwrap();
        let stats = server.join();
        assert_eq!(stats.offload_errors, 1);
        assert_eq!(stats.feature_offloads, 0, "rejected offloads are never counted");
        assert_eq!(stats.exec.submitted, 0, "the executor never sees the request");
    }

    /// A learner mid-update does not drain its telemetry feed; the
    /// bounded channel fills and serving sheds frames. The shed must be
    /// counted in `ServerStats::telemetry_drops`, never silent.
    #[test]
    fn undrained_telemetry_feed_counts_drops() {
        let n = 1;
        let pool = StatePool::new(
            n,
            StateNorm {
                lambda_tasks: 10.0,
                frame_s: 0.5,
                max_bits: 1e6,
                d_max: 100.0,
            },
        );
        let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![
            HybridAction::new(5, 0, 0.0, 1.0);
            n
        ])));
        let mut cfg = ServerConfig::new(n, Duration::from_millis(1), 5);
        // capacity-1 feed that nobody drains: a learner stuck in a long
        // PPO round, as far as the server can tell
        let (ttx, trx) = sync_channel(1);
        cfg.telemetry = Some(ttx);
        let (server, _downlinks) = EdgeServer::spawn(cfg, pool, dm, None).unwrap();
        server
            .uplink
            .send(Uplink::Report(UeStateReport {
                ue_id: 0,
                tasks_left: 5,
                compute_left_s: 0.0,
                offload_left_bits: 0.0,
                distance_m: 40.0,
            }))
            .unwrap();
        let stats = server.join(); // exits at max_frames
        assert_eq!(stats.frames, 5);
        assert_eq!(
            stats.telemetry_drops,
            stats.frames - 1,
            "every frame past the queue capacity is a counted drop"
        );
        // the one frame that fit is still delivered intact
        let first = trx.try_recv().expect("capacity-1 frame delivered");
        assert_eq!(first.actions.len(), n);
        assert!(trx.try_recv().is_err(), "shed frames never arrive late");
    }

    #[test]
    fn dropped_uplink_without_goodbye_shuts_down() {
        let n = 2;
        let pool = StatePool::new(
            n,
            StateNorm {
                lambda_tasks: 10.0,
                frame_s: 0.5,
                max_bits: 1e6,
                d_max: 100.0,
            },
        );
        let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![
            HybridAction::new(5, 0, 0.0, 1.0);
            n
        ])));
        // huge frame budget: only disconnection can end the loop quickly
        let cfg = ServerConfig::new(n, Duration::from_millis(5), usize::MAX);
        let (server, _downlinks) = EdgeServer::spawn(cfg, pool, dm, None).unwrap();
        server
            .uplink
            .send(Uplink::Report(UeStateReport {
                ue_id: 0,
                tasks_left: 1,
                compute_left_s: 0.0,
                offload_left_bits: 0.0,
                distance_m: 40.0,
            }))
            .unwrap();
        // UEs vanish without a Goodbye: dropping the only sender must shut
        // the server down promptly instead of spinning to max_frames
        drop(server.uplink.clone()); // exercise clone-then-drop too
        let EdgeServer { uplink, handle } = server;
        drop(uplink);
        let t0 = std::time::Instant::now();
        let stats = handle.join();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "server must exit promptly on full disconnection"
        );
        assert_eq!(stats.reports, 1);
    }
}
