//! The decision maker deployed at the edge (Sec. 3.1): maps the assembled
//! state-pool vector to a joint [`FrameDecision`] each frame.
//!
//! Wraps either trained MAHPPO actor networks (greedy at serving time) or
//! a baseline policy; the serving loop doesn't care which.

use anyhow::Result;

use super::protocol::FrameDecision;
use crate::env::HybridAction;
use crate::rl::sampling;
use crate::runtime::artifacts::ArtifactStore;
use crate::runtime::nets::ActorNet;

/// A serving-time decision source.
pub trait DecisionSource: Send {
    fn decide(&mut self, state: &[f32]) -> Result<Vec<HybridAction>>;
}

/// Greedy MAHPPO actors (the trained agent, deployed).
pub struct ActorDecision {
    actors: Vec<ActorNet>,
    p_max: f64,
    n_choices: usize,
}

impl ActorDecision {
    pub fn new(store: &ArtifactStore, n_ues: usize, p_max: f64, seed: u64) -> Result<ActorDecision> {
        let rl = store.rl()?;
        let actors = (0..n_ues)
            .map(|i| ActorNet::new(store, n_ues, seed.wrapping_add(i as u64)))
            .collect::<Result<Vec<_>>>()?;
        Ok(ActorDecision {
            actors,
            p_max,
            n_choices: rl.n_partition,
        })
    }

    /// Deploy trained actors (moves the nets out of a trainer).
    pub fn from_actors(actors: Vec<ActorNet>, p_max: f64, n_choices: usize) -> ActorDecision {
        ActorDecision {
            actors,
            p_max,
            n_choices,
        }
    }
}

impl DecisionSource for ActorDecision {
    fn decide(&mut self, state: &[f32]) -> Result<Vec<HybridAction>> {
        let mut out = Vec::with_capacity(self.actors.len());
        for actor in self.actors.iter_mut() {
            let o = actor.forward(state)?;
            let g = sampling::greedy_hybrid(&o);
            out.push(HybridAction::new(
                g.b.min(self.n_choices - 1),
                g.c,
                g.p_raw,
                self.p_max,
            ));
        }
        Ok(out)
    }
}

/// A fixed decision (Local / FixedSplit serving baselines).
pub struct StaticDecision {
    pub actions: Vec<HybridAction>,
}

impl DecisionSource for StaticDecision {
    fn decide(&mut self, _state: &[f32]) -> Result<Vec<HybridAction>> {
        Ok(self.actions.clone())
    }
}

/// The per-frame decision maker: numbers frames and delegates to a source.
pub struct DecisionMaker {
    source: Box<dyn DecisionSource>,
    frame: usize,
}

impl DecisionMaker {
    pub fn new(source: Box<dyn DecisionSource>) -> DecisionMaker {
        DecisionMaker { source, frame: 0 }
    }

    pub fn next_decision(&mut self, state: &[f32]) -> Result<FrameDecision> {
        let actions = self.source.decide(state)?;
        let d = FrameDecision {
            frame: self.frame,
            actions,
        };
        self.frame += 1;
        Ok(d)
    }

    pub fn frames_issued(&self) -> usize {
        self.frame
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_source_numbers_frames() {
        let a = vec![HybridAction::new(5, 0, 0.0, 1.0); 3];
        let mut dm = DecisionMaker::new(Box::new(StaticDecision { actions: a }));
        let d0 = dm.next_decision(&[0.0; 12]).unwrap();
        let d1 = dm.next_decision(&[0.0; 12]).unwrap();
        assert_eq!(d0.frame, 0);
        assert_eq!(d1.frame, 1);
        assert_eq!(d1.actions.len(), 3);
        assert_eq!(dm.frames_issued(), 2);
    }
}
