//! The decision maker deployed at the edge (Sec. 3.1): maps the assembled
//! state-pool vector to a joint [`FrameDecision`] each frame.
//!
//! Wraps either trained MAHPPO actor networks (greedy at serving time) or
//! a baseline policy; the serving loop doesn't care which. Policies are
//! **hot-swappable**: anyone holding a [`PolicyHandle`] (the online
//! learner, an operator console, a trainer in another thread) can
//! [`PolicyHandle::publish`] a fresh [`PolicySnapshot`]; the
//! [`DecisionMaker`] applies the latest pending snapshot atomically
//! *between* decision frames, so a swap never tears a broadcast and never
//! costs one (counter-verified in `rust/tests/integration_serving.rs`).

use std::sync::{Arc, Mutex, Weak};

use anyhow::{ensure, Result};

use super::protocol::FrameDecision;
use crate::env::HybridAction;
use crate::rl::checkpoint::{self, PolicySnapshot, TrainerCheckpoint};
use crate::rl::sampling;
use crate::runtime::artifacts::ArtifactStore;
use crate::runtime::nets::ActorNet;
use crate::util::sync::lock_unpoisoned;

/// A serving-time decision source.
pub trait DecisionSource: Send {
    /// The joint action for one frame. Shared, not owned: a fixed policy
    /// returns the same `Arc` every tick (a refcount bump, no copy), and
    /// the broadcast path clones it for free however many UEs subscribe.
    fn decide(&mut self, state: &[f32]) -> Result<Arc<[HybridAction]>>;

    /// Install a published policy snapshot. `Ok(true)` means the source
    /// now serves the new policy; the default `Ok(false)` means this
    /// source has nothing swappable (baselines), which is not an error.
    fn install(&mut self, _snap: &PolicySnapshot) -> Result<bool> {
        Ok(false)
    }
}

/// Greedy MAHPPO actors (the trained agent, deployed).
pub struct ActorDecision {
    actors: Vec<ActorNet>,
    p_max: f64,
    n_choices: usize,
}

impl ActorDecision {
    /// Deploy a **trained** policy from a checkpoint file — the default
    /// construction path, so a deployment always serves learned weights.
    /// (Use [`ActorDecision::untrained`] to explicitly serve fresh nets.)
    pub fn new(store: &ArtifactStore, path: impl AsRef<std::path::Path>) -> Result<ActorDecision> {
        Self::from_checkpoint(store, path)
    }

    /// Load the actor parameters persisted in a
    /// [`crate::rl::checkpoint`] file and wrap them for serving. The
    /// scenario saved alongside supplies `p_max`; the store supplies the
    /// compiled forward artifacts.
    pub fn from_checkpoint(
        store: &ArtifactStore,
        path: impl AsRef<std::path::Path>,
    ) -> Result<ActorDecision> {
        let path = path.as_ref();
        let cp = checkpoint::load(path)
            .map_err(|e| anyhow::anyhow!("loading policy from {}: {e}", path.display()))?;
        Self::from_trainer_checkpoint(store, &cp)
    }

    /// [`ActorDecision::from_checkpoint`], from an already-decoded
    /// checkpoint (e.g. one held in memory next to a live trainer).
    pub fn from_trainer_checkpoint(
        store: &ArtifactStore,
        cp: &TrainerCheckpoint,
    ) -> Result<ActorDecision> {
        let n_ues = cp.scenario.n_ues;
        ensure!(
            cp.actors.len() == n_ues,
            "checkpoint has {} actors for an N={n_ues} scenario",
            cp.actors.len()
        );
        let rl = store.rl()?;
        let mut actors = (0..n_ues)
            .map(|i| ActorNet::new(store, n_ues, cp.config.actor_seed(i)))
            .collect::<Result<Vec<_>>>()?;
        for (a, st) in actors.iter_mut().zip(&cp.actors) {
            a.restore(st)?;
        }
        Ok(ActorDecision {
            actors,
            p_max: cp.scenario.p_max,
            n_choices: rl.n_partition,
        })
    }

    /// Serve **randomly-initialized** actors (seeded fresh from the store
    /// spec). Explicitly named so a misconfigured deployment can't quietly
    /// serve noise; a stderr note marks every construction.
    pub fn untrained(
        store: &ArtifactStore,
        n_ues: usize,
        p_max: f64,
        seed: u64,
    ) -> Result<ActorDecision> {
        eprintln!(
            "note: serving UNTRAINED (randomly-initialized) actors for N={n_ues} — \
             decisions are noise until a policy is published or loaded"
        );
        let rl = store.rl()?;
        let actors = (0..n_ues)
            .map(|i| ActorNet::new(store, n_ues, seed.wrapping_add(i as u64)))
            .collect::<Result<Vec<_>>>()?;
        Ok(ActorDecision {
            actors,
            p_max,
            n_choices: rl.n_partition,
        })
    }

    /// Deploy trained actors (moves the nets out of a trainer).
    pub fn from_actors(actors: Vec<ActorNet>, p_max: f64, n_choices: usize) -> ActorDecision {
        ActorDecision {
            actors,
            p_max,
            n_choices,
        }
    }
}

impl DecisionSource for ActorDecision {
    fn decide(&mut self, state: &[f32]) -> Result<Arc<[HybridAction]>> {
        let mut out = Vec::with_capacity(self.actors.len());
        for actor in self.actors.iter_mut() {
            let o = actor.forward(state)?;
            let g = sampling::greedy_hybrid(&o);
            out.push(HybridAction::new(
                g.b.min(self.n_choices - 1),
                g.c,
                g.p_raw,
                self.p_max,
            ));
        }
        Ok(out.into())
    }

    /// Swap in new actor parameter vectors. All-or-nothing: lengths are
    /// validated for every actor before any net is touched, so a bad
    /// snapshot can never leave the policy half-swapped.
    fn install(&mut self, snap: &PolicySnapshot) -> Result<bool> {
        ensure!(
            snap.actors.len() == self.actors.len(),
            "policy snapshot has {} actors, serving {} UEs",
            snap.actors.len(),
            self.actors.len()
        );
        for (u, (a, p)) in self.actors.iter().zip(&snap.actors).enumerate() {
            ensure!(
                p.len() == a.params.len(),
                "actor {u} snapshot has {} params, net expects {}",
                p.len(),
                a.params.len()
            );
        }
        for (a, p) in self.actors.iter_mut().zip(&snap.actors) {
            a.set_params(p)?;
        }
        Ok(true)
    }
}

/// A fixed decision (Local / FixedSplit serving baselines). The joint
/// action is held behind an `Arc`, so every broadcast tick hands out the
/// same allocation — cloning the full vector per tick (the old behavior)
/// made the fixed baselines pay a per-frame copy that scaled with N.
pub struct StaticDecision {
    pub actions: Arc<[HybridAction]>,
}

impl StaticDecision {
    pub fn new(actions: impl Into<Arc<[HybridAction]>>) -> StaticDecision {
        StaticDecision {
            actions: actions.into(),
        }
    }
}

impl DecisionSource for StaticDecision {
    fn decide(&mut self, _state: &[f32]) -> Result<Arc<[HybridAction]>> {
        Ok(Arc::clone(&self.actions))
    }
}

/// A clonable publisher end of one or more [`DecisionMaker`] swap slots:
/// call [`PolicyHandle::publish`] from any thread to stage a new policy.
/// Each maker applies the **latest** staged snapshot between decision
/// frames (intermediate snapshots are superseded, never half-applied).
/// Every slot holds at most one snapshot, so publishing is bounded by
/// construction — a stalled maker can never accumulate a queue of stale
/// policies.
///
/// A handle minted by [`DecisionMaker::policy_handle`] targets that one
/// maker; [`PolicyHandle::fanout`] merges handles so a single publish
/// reaches every shard of a sharded server (see
/// [`super::shard`]) — the online [`super::learner`] keeps working
/// unchanged against either.
#[derive(Clone)]
pub struct PolicyHandle {
    slots: Vec<Weak<Mutex<Option<PolicySnapshot>>>>,
}

impl PolicyHandle {
    /// Stage `snap` for the next inter-frame swap point of every targeted
    /// maker, superseding any snapshot still pending. Non-blocking;
    /// returns `false` only when **no** targeted maker is alive anymore.
    pub fn publish(&self, snap: PolicySnapshot) -> bool {
        let mut any = false;
        for slot in &self.slots {
            let Some(slot) = slot.upgrade() else { continue };
            *lock_unpoisoned(&slot) = Some(snap.clone());
            any = true;
        }
        any
    }

    /// Merge handles into one that publishes to every underlying slot —
    /// the cross-shard policy fan-out. Order is irrelevant; dead slots
    /// are skipped at publish time.
    pub fn fanout(handles: impl IntoIterator<Item = PolicyHandle>) -> PolicyHandle {
        PolicyHandle {
            slots: handles.into_iter().flat_map(|h| h.slots).collect(),
        }
    }

    /// How many targeted makers are still alive (diagnostics).
    pub fn live_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.upgrade().is_some()).count()
    }
}

/// The per-frame decision maker: numbers frames, applies pending policy
/// swaps between them, and delegates to a source.
pub struct DecisionMaker {
    source: Box<dyn DecisionSource>,
    frame: usize,
    swap_slot: Arc<Mutex<Option<PolicySnapshot>>>,
    swaps_applied: usize,
    swap_errors: usize,
    policy_version: Option<u64>,
}

impl DecisionMaker {
    pub fn new(source: Box<dyn DecisionSource>) -> DecisionMaker {
        DecisionMaker {
            source,
            frame: 0,
            swap_slot: Arc::new(Mutex::new(None)),
            swaps_applied: 0,
            swap_errors: 0,
            policy_version: None,
        }
    }

    /// Mint a publisher for this maker's swap slot.
    pub fn policy_handle(&self) -> PolicyHandle {
        PolicyHandle {
            slots: vec![Arc::downgrade(&self.swap_slot)],
        }
    }

    /// Apply the latest staged snapshot, if any. A snapshot the source
    /// rejects (wrong shape) is logged and dropped — the old policy keeps
    /// serving; decisions must never stall on a bad publish.
    fn apply_pending_swap(&mut self) {
        let latest = lock_unpoisoned(&self.swap_slot).take();
        let Some(snap) = latest else { return };
        match self.source.install(&snap) {
            Ok(true) => {
                self.swaps_applied += 1;
                self.policy_version = Some(snap.version);
            }
            Ok(false) => {
                log::warn!(
                    "policy v{} published to a non-swappable decision source — ignored",
                    snap.version
                );
            }
            Err(e) => {
                self.swap_errors += 1;
                log::error!("rejected policy v{}: {e:#}", snap.version);
            }
        }
    }

    pub fn next_decision(&mut self, state: &[f32]) -> Result<FrameDecision> {
        // the inter-frame swap point: after the previous broadcast, before
        // this frame's actions are computed
        self.apply_pending_swap();
        let actions = self.source.decide(state)?;
        let d = FrameDecision {
            frame: self.frame,
            actions,
        };
        self.frame += 1;
        Ok(d)
    }

    pub fn frames_issued(&self) -> usize {
        self.frame
    }

    /// Swaps applied so far (a swap supersedes any older staged snapshots,
    /// which are not counted).
    pub fn swaps_applied(&self) -> usize {
        self.swaps_applied
    }

    /// Published snapshots rejected by the source (bad shape).
    pub fn swap_errors(&self) -> usize {
        self.swap_errors
    }

    /// Version of the last applied snapshot (None before any swap).
    pub fn policy_version(&self) -> Option<u64> {
        self.policy_version
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_source_numbers_frames() {
        let a = vec![HybridAction::new(5, 0, 0.0, 1.0); 3];
        let mut dm = DecisionMaker::new(Box::new(StaticDecision::new(a)));
        let d0 = dm.next_decision(&[0.0; 12]).unwrap();
        let d1 = dm.next_decision(&[0.0; 12]).unwrap();
        assert_eq!(d0.frame, 0);
        assert_eq!(d1.frame, 1);
        assert_eq!(d1.actions.len(), 3);
        assert_eq!(dm.frames_issued(), 2);
    }

    #[test]
    fn static_source_shares_one_allocation_across_ticks() {
        // the per-tick cost must be a refcount bump, not a vector clone:
        // every decision hands out the SAME allocation, with unchanged
        // contents (behavior-identical to the old cloning path)
        let a = vec![HybridAction::new(5, 0, 0.0, 1.0); 4];
        let mut dm = DecisionMaker::new(Box::new(StaticDecision::new(a.clone())));
        let d0 = dm.next_decision(&[0.0; 12]).unwrap();
        let d1 = dm.next_decision(&[0.0; 12]).unwrap();
        assert!(
            Arc::ptr_eq(&d0.actions, &d1.actions),
            "ticks must share one allocation"
        );
        assert_eq!(&d0.actions[..], &a[..], "shared actions must match the baseline");
        assert_eq!(d0.actions, d1.actions);
    }

    #[test]
    fn swap_to_static_source_is_ignored_not_fatal() {
        let a = vec![HybridAction::new(5, 0, 0.0, 1.0); 2];
        let mut dm = DecisionMaker::new(Box::new(StaticDecision::new(a.clone())));
        let handle = dm.policy_handle();
        assert!(handle.publish(PolicySnapshot {
            version: 1,
            actors: vec![vec![0.0; 4]; 2],
        }));
        let d = dm.next_decision(&[0.0; 8]).unwrap();
        assert_eq!(&d.actions[..], &a[..], "static decisions unchanged");
        assert_eq!(dm.swaps_applied(), 0);
        assert_eq!(dm.swap_errors(), 0);
        assert_eq!(dm.policy_version(), None);
    }

    #[test]
    fn publish_after_maker_drop_reports_failure() {
        let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![])));
        let handle = dm.policy_handle();
        drop(dm);
        assert!(!handle.publish(PolicySnapshot {
            version: 1,
            actors: vec![],
        }));
    }

    /// A swappable no-op source: `install` always accepts, so
    /// `swaps_applied` counts exactly the publishes a maker saw.
    struct Swappable;

    impl DecisionSource for Swappable {
        fn decide(&mut self, _state: &[f32]) -> Result<Arc<[HybridAction]>> {
            Ok(vec![].into())
        }
        fn install(&mut self, _snap: &PolicySnapshot) -> Result<bool> {
            Ok(true)
        }
    }

    #[test]
    fn fanout_publish_reaches_every_maker() {
        let mut a = DecisionMaker::new(Box::new(Swappable));
        let mut b = DecisionMaker::new(Box::new(Swappable));
        let c = DecisionMaker::new(Box::new(Swappable));
        let h = PolicyHandle::fanout([a.policy_handle(), b.policy_handle(), c.policy_handle()]);
        assert_eq!(h.live_slots(), 3);
        drop(c); // one shard gone: publish must still reach the others
        assert!(h.publish(PolicySnapshot {
            version: 7,
            actors: vec![],
        }));
        assert_eq!(h.live_slots(), 2);
        a.next_decision(&[]).unwrap();
        b.next_decision(&[]).unwrap();
        assert_eq!(a.swaps_applied(), 1, "shard A missed the fan-out");
        assert_eq!(b.swaps_applied(), 1, "shard B missed the fan-out");
        assert_eq!(a.policy_version(), Some(7));

        drop(a);
        drop(b);
        assert!(
            !h.publish(PolicySnapshot {
                version: 8,
                actors: vec![],
            }),
            "publish must report failure once every maker is gone"
        );
    }

    #[test]
    fn latest_staged_snapshot_wins_and_bad_shapes_are_rejected() {
        let store = ArtifactStore::native_demo();
        let n = 3;
        let mut dm = DecisionMaker::new(Box::new(
            ActorDecision::untrained(&store, n, 1.0, 7).unwrap(),
        ));
        let handle = dm.policy_handle();
        let d0 = dm.next_decision(&[0.25; 12]).unwrap();

        // a second, differently-seeded set of actors as the "new" policy
        let other = ActorDecision::untrained(&store, n, 1.0, 999).unwrap();
        let good = PolicySnapshot {
            version: 2,
            actors: other.actors.iter().map(|a| a.params.clone()).collect(),
        };
        // stage a bad snapshot first, then the good one: only the latest
        // is applied, so the bad one is superseded without error
        handle.publish(PolicySnapshot {
            version: 1,
            actors: vec![vec![0.0; 3]; n],
        });
        handle.publish(good.clone());
        let d1 = dm.next_decision(&[0.25; 12]).unwrap();
        assert_eq!(dm.swaps_applied(), 1);
        assert_eq!(dm.policy_version(), Some(2));
        assert_ne!(d0.actions, d1.actions, "swap must change served decisions");

        // a lone bad snapshot is rejected and the old policy keeps serving
        handle.publish(PolicySnapshot {
            version: 3,
            actors: vec![vec![0.0; 3]; n],
        });
        let d2 = dm.next_decision(&[0.25; 12]).unwrap();
        assert_eq!(dm.swap_errors(), 1);
        assert_eq!(dm.policy_version(), Some(2));
        assert_eq!(d2.actions, d1.actions);
    }
}
