//! Content-addressed offload result cache (DESIGN.md §Data-Plane,
//! ROADMAP item 5's `EdgeCache` shape).
//!
//! Identical offloads recur constantly in the paper's regime — a fleet of
//! UEs sampling the same task distribution re-sends byte-identical
//! payloads at the same partition point — yet every one costs a full
//! back-model pass. This cache short-circuits them: results are keyed on
//! **content**, `(partition point b, calibration bits, payload bytes)`,
//! so a hit is *bit-identical* to a recompute by construction (same
//! deterministic compute, same inputs), never "close enough".
//!
//! Layout:
//!
//! * The hashed **key head** — FNV-1a 64 of the payload, its length, `b`,
//!   and the calibration `f32::to_bits` pair — addresses a bucket; the
//!   stored payload bytes are then compared in full, so a forced hash
//!   collision degrades to a miss, never a wrong result (property-tested
//!   in `rust/tests/proptests.rs`).
//! * Entries live in a slab threaded onto a doubly-linked LRU list;
//!   capacity is enforced by evicting the tail. Evicted payload buffers
//!   return to a [`FramePool`], so a churning cache recycles its buffers
//!   instead of re-allocating per insert.
//! * Results are inserted when a completion arrives, via a **bounded**
//!   pending map noted at submit time (an unbounded in-flight map would
//!   be a memory hole under an offload flood).
//!
//! Single-threaded by design: the cache is owned by one `server_loop`
//! (one per shard), consulted before the executor — no lock anywhere.

use std::collections::HashMap;

use super::protocol::{InferenceResult, OffloadRequest};
use super::wire::FramePool;

/// Pending-insert notes retained at once, as a multiple of the cache
/// capacity (in-flight offloads beyond this simply go uncached).
const PENDING_FACTOR: usize = 2;

/// FNV-1a 64-bit — the hand-rolled content hash (no external deps; the
/// full-key byte compare backstops its collisions).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The hashed head of a cache key: everything *except* the payload bytes
/// themselves. Two requests with equal heads are only the same entry if
/// their payloads also compare equal byte-for-byte — the head addresses,
/// the bytes decide.
#[doc(hidden)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KeyHead {
    /// FNV-1a 64 of the payload bytes.
    pub payload_hash: u64,
    pub payload_len: usize,
    /// Partition point (0 = raw input, 1..=4 = AE-coded cut).
    pub b: usize,
    /// AE calibration as exact bit patterns (`f32::to_bits`), `None` for
    /// raw offloads — bitwise, so `-0.0` vs `0.0` or NaN payloads can
    /// never alias across calibrations.
    pub calibration: Option<(u32, u32)>,
}

/// Build the key head for one request's identifying fields.
#[doc(hidden)]
pub fn key_head(b: usize, calibration: Option<(f32, f32)>, payload: &[u8]) -> KeyHead {
    KeyHead {
        payload_hash: fnv1a64(payload),
        payload_len: payload.len(),
        b,
        calibration: calibration.map(|(lo, hi)| (lo.to_bits(), hi.to_bits())),
    }
}

/// Cache counters, folded into `ServerStats::cache` after shutdown.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Lookups served from memory (the executor never saw the request).
    pub hits: u64,
    /// Lookups that fell through to compute.
    pub misses: u64,
    /// Results inserted after a completed compute.
    pub insertions: u64,
    /// Entries evicted by the LRU capacity bound.
    pub evictions: u64,
    /// Payload bytes whose edge compute was skipped (sum over hits).
    pub bytes_saved: u64,
}

/// One cached result plus its LRU threading.
struct Entry {
    head: KeyHead,
    /// The full payload bytes — the collision backstop.
    payload: Vec<u8>,
    logits: Vec<f32>,
    argmax: usize,
    edge_latency_s: f64,
    prev: Option<usize>,
    next: Option<usize>,
}

/// An offload noted at submit time, awaiting its completion.
struct Pending {
    head: KeyHead,
    payload: Vec<u8>,
}

/// Bounded-LRU content-addressed offload result cache. `cap` = 0
/// disables every operation (today's recompute-always behavior at zero
/// cost: one branch per call).
pub struct OffloadCache {
    cap: usize,
    /// `head → slab indices` (a tiny chain: only true hash collisions
    /// share a bucket).
    map: HashMap<KeyHead, Vec<usize>>,
    slots: Vec<Option<Entry>>,
    free: Vec<usize>,
    /// Most-recently-used end of the LRU list.
    lru_head: Option<usize>,
    /// Eviction end.
    lru_tail: Option<usize>,
    len: usize,
    /// In-flight (ue_id, task_id) → key + payload copy, bounded by
    /// `PENDING_FACTOR * cap`.
    pending: HashMap<(usize, u64), Pending>,
    /// Recycler for payload buffers (insert copies in, eviction puts
    /// back) — a churning cache stops allocating once warm.
    pool: FramePool,
    stats: CacheStats,
}

impl OffloadCache {
    pub fn new(cap: usize) -> OffloadCache {
        OffloadCache {
            cap,
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            lru_head: None,
            lru_tail: None,
            len: 0,
            pending: HashMap::new(),
            pool: FramePool::new(),
            stats: CacheStats::default(),
        }
    }

    /// Whether lookups can ever hit (capacity > 0).
    pub fn enabled(&self) -> bool {
        self.cap > 0
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Look one request up. A hit rebuilds the stored result under the
    /// requester's `(ue_id, task_id)` — logits, argmax and latency are
    /// the cached compute's exact values — and refreshes LRU order.
    pub fn lookup(&mut self, req: &OffloadRequest) -> Option<InferenceResult> {
        if self.cap == 0 {
            return None;
        }
        let head = key_head(req.b, req.calibration, &req.payload);
        self.lookup_keyed(head, &req.payload, req.ue_id, req.task_id)
    }

    /// [`OffloadCache::lookup`] with a caller-supplied head — exposed so
    /// collision tests can force two different payloads onto one head and
    /// prove the byte compare still separates them.
    #[doc(hidden)]
    pub fn lookup_keyed(
        &mut self,
        head: KeyHead,
        payload: &[u8],
        ue_id: usize,
        task_id: u64,
    ) -> Option<InferenceResult> {
        if self.cap == 0 {
            return None;
        }
        let found = self.map.get(&head).and_then(|chain| {
            chain.iter().copied().find(|&i| {
                self.slots
                    .get(i)
                    .and_then(Option::as_ref)
                    .is_some_and(|e| e.payload == payload)
            })
        });
        let Some(i) = found else {
            self.stats.misses += 1;
            return None;
        };
        self.detach(i);
        self.push_front(i);
        self.stats.hits += 1;
        self.stats.bytes_saved += payload.len() as u64;
        let e = self.slots.get(i).and_then(Option::as_ref)?;
        Some(InferenceResult {
            ue_id,
            task_id,
            logits: e.logits.clone(),
            argmax: e.argmax,
            edge_latency_s: e.edge_latency_s,
        })
    }

    /// Note an in-flight offload so its completion can be inserted.
    /// Bounded: once `PENDING_FACTOR * cap` notes are outstanding, new
    /// offloads simply go uncached.
    pub fn note_pending(&mut self, req: &OffloadRequest) {
        if self.cap == 0 || self.pending.len() >= PENDING_FACTOR * self.cap {
            return;
        }
        let head = key_head(req.b, req.calibration, &req.payload);
        let mut payload = self.pool.get(req.payload.len());
        payload.extend_from_slice(&req.payload);
        self.pending.insert((req.ue_id, req.task_id), Pending { head, payload });
    }

    /// Settle the pending note for `(ue_id, task_id)`: insert the result
    /// on success, recycle the payload copy on failure. A completion with
    /// no note (cache off, note bound hit) is a no-op.
    pub fn complete(&mut self, ue_id: usize, task_id: u64, result: Option<&InferenceResult>) {
        let Some(p) = self.pending.remove(&(ue_id, task_id)) else {
            return;
        };
        match result {
            Some(r) => self.insert_keyed(p.head, p.payload, r),
            None => self.pool.put(p.payload),
        }
    }

    /// Insert one computed result (takes ownership of the payload copy).
    /// Re-inserting an existing key only refreshes its LRU position.
    #[doc(hidden)]
    pub fn insert_keyed(&mut self, head: KeyHead, payload: Vec<u8>, result: &InferenceResult) {
        if self.cap == 0 {
            self.pool.put(payload);
            return;
        }
        // already cached (a duplicate completed while this one was in
        // flight)? refresh recency, recycle the copy, done
        let existing = self.map.get(&head).and_then(|chain| {
            chain.iter().copied().find(|&i| {
                self.slots
                    .get(i)
                    .and_then(Option::as_ref)
                    .is_some_and(|e| e.payload == payload)
            })
        });
        if let Some(i) = existing {
            self.detach(i);
            self.push_front(i);
            self.pool.put(payload);
            return;
        }
        while self.len >= self.cap {
            self.evict_tail();
        }
        let entry = Entry {
            head,
            payload,
            logits: result.logits.clone(),
            argmax: result.argmax,
            edge_latency_s: result.edge_latency_s,
            prev: None,
            next: None,
        };
        let i = match self.free.pop() {
            Some(i) => {
                if let Some(slot) = self.slots.get_mut(i) {
                    *slot = Some(entry);
                }
                i
            }
            None => {
                self.slots.push(Some(entry));
                self.slots.len() - 1
            }
        };
        self.map.entry(head).or_default().push(i);
        self.push_front(i);
        self.len += 1;
        self.stats.insertions += 1;
    }

    /// Unlink slab index `i` from the LRU list (no-op if absent).
    fn detach(&mut self, i: usize) {
        let Some((prev, next)) = self
            .slots
            .get(i)
            .and_then(Option::as_ref)
            .map(|e| (e.prev, e.next))
        else {
            return;
        };
        match prev {
            Some(p) => {
                if let Some(Some(e)) = self.slots.get_mut(p) {
                    e.next = next;
                }
            }
            None => self.lru_head = next,
        }
        match next {
            Some(n) => {
                if let Some(Some(e)) = self.slots.get_mut(n) {
                    e.prev = prev;
                }
            }
            None => self.lru_tail = prev,
        }
        if let Some(Some(e)) = self.slots.get_mut(i) {
            e.prev = None;
            e.next = None;
        }
    }

    /// Link slab index `i` in as most-recently-used.
    fn push_front(&mut self, i: usize) {
        let old = self.lru_head;
        if let Some(Some(e)) = self.slots.get_mut(i) {
            e.prev = None;
            e.next = old;
        }
        if let Some(h) = old {
            if let Some(Some(e)) = self.slots.get_mut(h) {
                e.prev = Some(i);
            }
        }
        self.lru_head = Some(i);
        if self.lru_tail.is_none() {
            self.lru_tail = Some(i);
        }
    }

    /// Evict the least-recently-used entry, recycling its payload buffer.
    fn evict_tail(&mut self) {
        let Some(t) = self.lru_tail else {
            return;
        };
        self.detach(t);
        let Some(entry) = self.slots.get_mut(t).and_then(Option::take) else {
            return;
        };
        if let Some(chain) = self.map.get_mut(&entry.head) {
            chain.retain(|&i| i != t);
            if chain.is_empty() {
                self.map.remove(&entry.head);
            }
        }
        self.pool.put(entry.payload);
        self.free.push(t);
        self.len -= 1;
        self.stats.evictions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(ue_id: usize, task_id: u64, b: usize, payload: &[u8]) -> OffloadRequest {
        OffloadRequest {
            ue_id,
            task_id,
            b,
            payload: payload.to_vec(),
            calibration: if b >= 1 { Some((-1.0, 1.0)) } else { None },
        }
    }

    fn result_for(r: &OffloadRequest, salt: f32) -> InferenceResult {
        InferenceResult {
            ue_id: r.ue_id,
            task_id: r.task_id,
            logits: vec![salt, salt + 1.0, salt + 2.0],
            argmax: 2,
            edge_latency_s: 0.004,
        }
    }

    /// note → complete → lookup under a new (ue, task) serves the exact
    /// stored numbers, re-addressed to the requester.
    #[test]
    fn hit_replays_the_stored_result_for_a_new_requester() {
        let mut cache = OffloadCache::new(4);
        let a = req(0, 1, 2, b"payload-bytes");
        cache.note_pending(&a);
        assert!(cache.lookup(&a).is_none(), "cold cache must miss");
        cache.complete(0, 1, Some(&result_for(&a, 5.0)));
        assert_eq!(cache.len(), 1);

        let b = req(3, 99, 2, b"payload-bytes"); // different UE, same content
        let hit = cache.lookup(&b).expect("identical content must hit");
        assert_eq!(hit.ue_id, 3);
        assert_eq!(hit.task_id, 99);
        assert_eq!(hit.logits, vec![5.0, 6.0, 7.0]);
        assert_eq!(hit.argmax, 2);
        assert_eq!(hit.edge_latency_s, 0.004);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 1, 1));
        assert_eq!(s.bytes_saved, b"payload-bytes".len() as u64);
    }

    /// Same payload, different partition point or calibration: distinct
    /// keys, no cross-serving.
    #[test]
    fn partition_and_calibration_partition_the_key_space() {
        let mut cache = OffloadCache::new(8);
        let a = req(0, 1, 1, b"shared");
        cache.note_pending(&a);
        cache.complete(0, 1, Some(&result_for(&a, 1.0)));

        let other_b = req(0, 2, 2, b"shared");
        assert!(cache.lookup(&other_b).is_none(), "different b must miss");
        let mut other_cal = req(0, 3, 1, b"shared");
        other_cal.calibration = Some((-1.0, 1.5));
        assert!(cache.lookup(&other_cal).is_none(), "different calibration must miss");
        let raw = req(0, 4, 0, b"shared");
        assert!(cache.lookup(&raw).is_none(), "raw (no calibration) must miss");
    }

    /// Two payloads forced onto one key head (a simulated FNV collision)
    /// stay separate entries: the full byte compare decides.
    #[test]
    fn forced_head_collision_still_misses_on_byte_compare() {
        let mut cache = OffloadCache::new(8);
        let shared = key_head(1, Some((-1.0, 1.0)), b"aaaa");
        let r1 = InferenceResult {
            ue_id: 0,
            task_id: 1,
            logits: vec![1.0],
            argmax: 0,
            edge_latency_s: 0.001,
        };
        cache.insert_keyed(shared, b"aaaa".to_vec(), &r1);
        // same head, different bytes: must MISS, never serve r1
        assert!(cache.lookup_keyed(shared, b"bbbb", 5, 50).is_none());
        // and inserting the second under the same head keeps both
        let r2 = InferenceResult {
            ue_id: 0,
            task_id: 2,
            logits: vec![2.0],
            argmax: 0,
            edge_latency_s: 0.002,
        };
        cache.insert_keyed(shared, b"bbbb".to_vec(), &r2);
        assert_eq!(cache.len(), 2);
        let h1 = cache.lookup_keyed(shared, b"aaaa", 9, 90).expect("first entry");
        assert_eq!(h1.logits, vec![1.0]);
        let h2 = cache.lookup_keyed(shared, b"bbbb", 9, 91).expect("second entry");
        assert_eq!(h2.logits, vec![2.0]);
    }

    /// Capacity evicts least-recently-used first; a lookup refreshes
    /// recency; eviction recycles payload buffers through the pool.
    #[test]
    fn lru_evicts_the_coldest_entry() {
        let mut cache = OffloadCache::new(2);
        for (t, p) in [(1u64, b"one!"), (2, b"two!")] {
            let r = req(0, t, 0, p);
            cache.note_pending(&r);
            cache.complete(0, t, Some(&result_for(&r, t as f32)));
        }
        // touch "one!" so "two!" is the LRU tail
        assert!(cache.lookup(&req(0, 10, 0, b"one!")).is_some());
        let r3 = req(0, 3, 0, b"three");
        cache.note_pending(&r3);
        cache.complete(0, 3, Some(&result_for(&r3, 3.0)));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.lookup(&req(0, 11, 0, b"one!")).is_some(), "refreshed entry survives");
        assert!(cache.lookup(&req(0, 12, 0, b"two!")).is_none(), "LRU tail was evicted");
        assert!(cache.lookup(&req(0, 13, 0, b"three")).is_some());
        let (pool_hits, _) = (cache.pool.stats().0, ());
        assert!(pool_hits >= 1, "evicted buffers must recycle through the pool");
    }

    /// cap = 0 disables everything — no notes, no inserts, no hits.
    #[test]
    fn zero_capacity_is_fully_off() {
        let mut cache = OffloadCache::new(0);
        assert!(!cache.enabled());
        let a = req(0, 1, 0, b"x");
        cache.note_pending(&a);
        cache.complete(0, 1, Some(&result_for(&a, 1.0)));
        assert!(cache.lookup(&a).is_none());
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.stats(), CacheStats::default());
    }

    /// Failed completions recycle the note without inserting; the pending
    /// map is bounded by `PENDING_FACTOR * cap`.
    #[test]
    fn failures_and_floods_never_grow_state() {
        let mut cache = OffloadCache::new(2);
        let a = req(0, 1, 0, b"will-fail");
        cache.note_pending(&a);
        cache.complete(0, 1, None);
        assert_eq!(cache.len(), 0);
        assert!(cache.lookup(&req(0, 2, 0, b"will-fail")).is_none());
        // flood the pending map: it must stop at the bound
        for t in 0..100u64 {
            cache.note_pending(&req(0, t + 10, 0, &t.to_le_bytes()));
        }
        assert!(cache.pending.len() <= PENDING_FACTOR * 2, "pending map must stay bounded");
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
