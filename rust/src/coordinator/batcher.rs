//! Dynamic batching of edge-side full-model executions.
//!
//! Raw-input offloads (b = 0) all run the same full backbone on the edge;
//! batching them through the `{model}_full_b8` artifact amortizes dispatch
//! overhead. The batcher accumulates requests until `max_batch` is reached
//! or `max_wait` elapses since the first queued request, then flushes —
//! the standard dynamic-batching policy of serving systems (vLLM-style),
//! here at the scale this paper needs.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::artifacts::ArtifactStore;
use crate::runtime::backend::Executable;
use crate::runtime::tensor::TensorView;

/// One queued full-model inference.
#[derive(Debug, Clone)]
pub struct BatchItem {
    pub ue_id: usize,
    pub task_id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
}

/// One completed inference from a flush.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    pub ue_id: usize,
    pub task_id: u64,
    pub logits: Vec<f32>,
    /// Time spent waiting in the queue before the flush.
    pub queue_wait: Duration,
}

pub struct DynamicBatcher {
    exe_b8: Arc<dyn Executable>,
    exe_b1: Arc<dyn Executable>,
    /// Model weight vector, pre-wrapped as a backend input (loop-invariant).
    weights: TensorView,
    image_elems: usize,
    image_shape1: Vec<usize>,
    num_classes: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    queue: VecDeque<BatchItem>,
}

impl DynamicBatcher {
    pub fn new(store: &ArtifactStore, model: &str, max_wait: Duration) -> Result<DynamicBatcher> {
        let meta = store.model(model)?;
        let hw = meta.input_hw;
        let weights = TensorView::f32(store.model_weights(model)?, vec![meta.weights_size])?;
        Ok(DynamicBatcher {
            exe_b8: store.load(&format!("{model}_full_b8"))?,
            exe_b1: store.load(&format!("{model}_full_b1"))?,
            weights,
            image_elems: 3 * hw * hw,
            image_shape1: vec![1, 3, hw, hw],
            num_classes: meta.num_classes,
            max_batch: 8,
            max_wait,
            queue: VecDeque::new(),
        })
    }

    pub fn push(&mut self, item: BatchItem) {
        self.queue.push_back(item);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Should we flush now? Full batch, or the oldest item has waited long
    /// enough.
    pub fn should_flush(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queue.len() >= self.max_batch
            || now.duration_since(self.queue[0].enqueued) >= self.max_wait
    }

    /// Execute up to `max_batch` queued items. Batches of exactly
    /// `max_batch` ride the b8 artifact (padded otherwise only when at
    /// least half full — below that the b1 artifact per item is cheaper).
    pub fn flush(&mut self) -> Result<Vec<BatchOutput>> {
        let now = Instant::now();
        let take = self.queue.len().min(self.max_batch);
        let items: Vec<BatchItem> = self.queue.drain(..take).collect();
        if items.is_empty() {
            return Ok(Vec::new());
        }

        let logits_all: Vec<Vec<f32>> = if items.len() * 2 >= self.max_batch {
            // pad to the fixed b8 shape
            let mut flat = Vec::with_capacity(self.max_batch * self.image_elems);
            for it in &items {
                flat.extend_from_slice(&it.image);
            }
            flat.resize(self.max_batch * self.image_elems, 0.0);
            let hw_shape = vec![
                self.max_batch,
                self.image_shape1[1],
                self.image_shape1[2],
                self.image_shape1[3],
            ];
            let batch = TensorView::f32(flat, hw_shape)?;
            let outs = self.exe_b8.call_refs(&[&self.weights, &batch])?;
            let all = outs[0].clone().into_f32s()?;
            items
                .iter()
                .enumerate()
                .map(|(i, _)| all[i * self.num_classes..(i + 1) * self.num_classes].to_vec())
                .collect()
        } else {
            let mut out = Vec::with_capacity(items.len());
            for it in &items {
                let image = TensorView::f32(it.image.clone(), self.image_shape1.clone())?;
                let outs = self.exe_b1.call_refs(&[&self.weights, &image])?;
                out.push(outs[0].clone().into_f32s()?);
            }
            out
        };

        Ok(items
            .into_iter()
            .zip(logits_all)
            .map(|(it, logits)| BatchOutput {
                ue_id: it.ue_id,
                task_id: it.task_id,
                logits,
                queue_wait: now.duration_since(it.enqueued),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flush_policy_without_artifacts() {
        // policy logic is artifact-independent: emulate with a queue only
        let now = Instant::now();
        let old = now - Duration::from_millis(100);
        // should_flush logic exercised through a zero-capacity shim is not
        // constructible without artifacts; validate the two predicates
        // directly instead.
        let wait = Duration::from_millis(50);
        assert!(now.duration_since(old) >= wait);
        assert!((8usize) >= 8);
    }
}
