//! Dynamic batching of edge-side full-model executions.
//!
//! Raw-input offloads (b = 0) all run the same full backbone on the edge;
//! batching them through the `{model}_full_b8` artifact amortizes dispatch
//! overhead. The subsystem is split along the dispatcher/worker seam of the
//! offload executor (`coordinator::executor`):
//!
//! * [`DynamicBatcher`] — the accumulation/flush *policy* (vLLM-style):
//!   queue requests until `max_batch` is reached or `max_wait` elapses
//!   since the first queued request, then hand out a batch. Owned by the
//!   dispatch side (the server loop's executor); holds no executables.
//! * [`BatchRunner`] — the *execution*: drives a taken batch through the
//!   fixed-shape b8 artifact (padded) or per-item b1, whichever is cheaper
//!   at the batch's occupancy. Shared with the worker pool.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::artifacts::ArtifactStore;
use crate::runtime::backend::Executable;
use crate::runtime::tensor::TensorView;

/// Anything the batcher can age: exposes its enqueue time, the single
/// source of truth for both the flush policy and queue-wait reporting.
pub trait Stamped {
    fn enqueued(&self) -> Instant;
}

/// One queued full-model inference.
#[derive(Debug, Clone)]
pub struct BatchItem {
    pub ue_id: usize,
    pub task_id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
}

impl Stamped for BatchItem {
    fn enqueued(&self) -> Instant {
        self.enqueued
    }
}

/// One completed inference from a flush.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    pub ue_id: usize,
    pub task_id: u64,
    pub logits: Vec<f32>,
    /// Time spent waiting in the queue before the flush.
    pub queue_wait: Duration,
}

/// The accumulation/flush policy: when to turn queued requests into a
/// batch. Pure bookkeeping, generic over the queued item (the executor
/// queues undecoded raw payloads so the decode cost stays off the server
/// thread; in-process users queue [`BatchItem`]s directly) — execution
/// lives in [`BatchRunner`].
pub struct DynamicBatcher<T: Stamped> {
    queue: VecDeque<T>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl<T: Stamped> DynamicBatcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> DynamicBatcher<T> {
        DynamicBatcher {
            queue: VecDeque::new(),
            max_batch: max_batch.max(1),
            max_wait,
        }
    }

    pub fn push(&mut self, item: T) {
        self.queue.push_back(item);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Should we flush now? Full batch, or the oldest item has waited long
    /// enough.
    pub fn should_flush(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queue.len() >= self.max_batch
            || now.duration_since(self.queue[0].enqueued()) >= self.max_wait
    }

    /// Drain up to `max_batch` queued items into one batch.
    pub fn take_batch(&mut self) -> Vec<T> {
        let take = self.queue.len().min(self.max_batch);
        self.queue.drain(..take).collect()
    }
}

/// Executes batches over the full-model artifacts. Batches at least half
/// the b8 wire shape ride the (padded) b8 artifact; below that the b1
/// artifact per item is cheaper. Oversized batches run in wire-shape
/// chunks.
pub struct BatchRunner {
    exe_b8: Arc<dyn Executable>,
    exe_b1: Arc<dyn Executable>,
    /// Model weight vector, pre-wrapped as a backend input (loop-invariant).
    weights: TensorView,
    image_elems: usize,
    image_shape1: Vec<usize>,
    num_classes: usize,
    /// Fixed batch dimension of `exe_b8`.
    wire_batch: usize,
}

impl BatchRunner {
    pub fn from_store(store: &ArtifactStore, model: &str) -> Result<BatchRunner> {
        let meta = store.model(model)?;
        let hw = meta.input_hw;
        let weights = TensorView::f32(store.model_weights(model)?, vec![meta.weights_size])?;
        Ok(BatchRunner::from_parts(
            store.load(&format!("{model}_full_b8"))?,
            store.load(&format!("{model}_full_b1"))?,
            weights,
            vec![1, 3, hw, hw],
            meta.num_classes,
            8,
        ))
    }

    /// Assemble from explicit executables — the seam the mock-`Executable`
    /// tests and alternative backends use.
    pub fn from_parts(
        exe_b8: Arc<dyn Executable>,
        exe_b1: Arc<dyn Executable>,
        weights: TensorView,
        image_shape1: Vec<usize>,
        num_classes: usize,
        wire_batch: usize,
    ) -> BatchRunner {
        BatchRunner {
            exe_b8,
            exe_b1,
            weights,
            image_elems: image_shape1.iter().skip(1).product(),
            image_shape1,
            num_classes,
            wire_batch: wire_batch.max(1),
        }
    }

    pub fn wire_batch(&self) -> usize {
        self.wire_batch
    }

    pub fn image_elems(&self) -> usize {
        self.image_elems
    }

    /// Execute a taken batch; outputs preserve item order.
    pub fn run(&self, items: Vec<BatchItem>) -> Result<Vec<BatchOutput>> {
        let now = Instant::now();
        let mut out = Vec::with_capacity(items.len());
        for chunk in items.chunks(self.wire_batch) {
            self.run_chunk(chunk, now, &mut out)?;
        }
        Ok(out)
    }

    fn run_chunk(
        &self,
        items: &[BatchItem],
        now: Instant,
        out: &mut Vec<BatchOutput>,
    ) -> Result<()> {
        let logits_all: Vec<Vec<f32>> = if items.len() * 2 >= self.wire_batch {
            // pad to the fixed b8 shape
            let mut flat = Vec::with_capacity(self.wire_batch * self.image_elems);
            for it in items {
                // a wrong-length image would silently shift every later
                // item's logits in the flat packing; fail loudly instead
                // (the b1 path gets the same check from tensor shaping)
                if it.image.len() != self.image_elems {
                    bail!(
                        "batch item task {} image has {} elements; expected {}",
                        it.task_id,
                        it.image.len(),
                        self.image_elems
                    );
                }
                flat.extend_from_slice(&it.image);
            }
            flat.resize(self.wire_batch * self.image_elems, 0.0);
            let hw_shape = vec![
                self.wire_batch,
                self.image_shape1[1],
                self.image_shape1[2],
                self.image_shape1[3],
            ];
            let batch = TensorView::f32(flat, hw_shape)?;
            let outs = self.exe_b8.call_refs(&[&self.weights, &batch])?;
            let all = outs[0].clone().into_f32s()?;
            // a short output would panic the per-item slicing below
            if all.len() != self.wire_batch * self.num_classes {
                bail!(
                    "b8 artifact returned {} logits; expected {} ({} x {})",
                    all.len(),
                    self.wire_batch * self.num_classes,
                    self.wire_batch,
                    self.num_classes
                );
            }
            items
                .iter()
                .enumerate()
                .map(|(i, _)| all[i * self.num_classes..(i + 1) * self.num_classes].to_vec())
                .collect()
        } else {
            let mut lg = Vec::with_capacity(items.len());
            for it in items {
                let image = TensorView::f32(it.image.clone(), self.image_shape1.clone())?;
                let outs = self.exe_b1.call_refs(&[&self.weights, &image])?;
                lg.push(outs[0].clone().into_f32s()?);
            }
            lg
        };

        for (it, logits) in items.iter().zip(logits_all) {
            out.push(BatchOutput {
                ue_id: it.ue_id,
                task_id: it.task_id,
                logits,
                queue_wait: now.duration_since(it.enqueued),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::ExecStats;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// A fake full-model artifact: logit c of image i = sum(image_i) + c,
    /// so outputs identify their input and the call count identifies the
    /// b1-vs-b8 routing.
    struct MockExe {
        name: String,
        batch: usize,
        classes: usize,
        calls: AtomicU64,
    }

    impl MockExe {
        fn new(name: &str, batch: usize, classes: usize) -> Arc<MockExe> {
            Arc::new(MockExe {
                name: name.into(),
                batch,
                classes,
                calls: AtomicU64::new(0),
            })
        }

        fn calls(&self) -> u64 {
            self.calls.load(Ordering::Relaxed)
        }
    }

    impl Executable for MockExe {
        fn name(&self) -> &str {
            &self.name
        }

        fn call_refs(&self, inputs: &[&TensorView]) -> Result<Vec<TensorView>> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            let images = inputs[1].f32s()?;
            let elems = images.len() / self.batch;
            let mut out = Vec::with_capacity(self.batch * self.classes);
            for b in 0..self.batch {
                let s: f32 = images[b * elems..(b + 1) * elems].iter().sum();
                for c in 0..self.classes {
                    out.push(s + c as f32);
                }
            }
            Ok(vec![TensorView::f32(out, vec![self.batch, self.classes])?])
        }

        fn stats(&self) -> ExecStats {
            ExecStats {
                calls: self.calls(),
                total_ns: 0,
            }
        }
    }

    const ELEMS: usize = 4; // 1x1x2x2 images
    const CLASSES: usize = 3;

    fn runner(wire_batch: usize) -> (BatchRunner, Arc<MockExe>, Arc<MockExe>) {
        let b8 = MockExe::new("mock_full_b8", wire_batch, CLASSES);
        let b1 = MockExe::new("mock_full_b1", 1, CLASSES);
        let weights = TensorView::f32(vec![0.0], vec![1]).unwrap();
        let r = BatchRunner::from_parts(
            b8.clone(),
            b1.clone(),
            weights,
            vec![1, 1, 2, 2],
            CLASSES,
            wire_batch,
        );
        (r, b8, b1)
    }

    fn item(task: u64, fill: f32) -> BatchItem {
        BatchItem {
            ue_id: task as usize % 3,
            task_id: task,
            image: vec![fill; ELEMS],
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn half_full_batches_ride_b8_padded() {
        let (r, b8, b1) = runner(4);
        // 2 items = exactly half of the wire shape -> b8, padded
        let out = r.run(vec![item(0, 1.0), item(1, 2.0)]).unwrap();
        assert_eq!((b8.calls(), b1.calls()), (1, 0));
        assert_eq!(out.len(), 2, "padding rows must not leak into outputs");
        // logits identify their input image through the mock's sum rule
        assert_eq!(out[0].logits, vec![4.0, 5.0, 6.0]);
        assert_eq!(out[1].logits, vec![8.0, 9.0, 10.0]);
        assert_eq!(out[1].task_id, 1);
    }

    #[test]
    fn below_half_full_routes_to_b1_per_item() {
        let (r, b8, b1) = runner(8);
        let out = r.run(vec![item(0, 1.0), item(1, 3.0), item(2, 5.0)]).unwrap();
        assert_eq!((b8.calls(), b1.calls()), (0, 3), "3 < 8/2 -> per-item b1");
        assert_eq!(out.len(), 3);
        assert_eq!(out[2].logits[0], 20.0);
    }

    #[test]
    fn oversized_batches_run_in_wire_chunks() {
        let (r, b8, b1) = runner(4);
        // 5 items: one full b8 chunk + a single below-half leftover on b1
        let out = r.run((0..5).map(|i| item(i, 1.0)).collect()).unwrap();
        assert_eq!((b8.calls(), b1.calls()), (1, 1));
        assert_eq!(out.len(), 5);
        let ids: Vec<u64> = out.iter().map(|o| o.task_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4], "outputs preserve item order");
    }

    #[test]
    fn flush_policy_size_and_age() {
        let mut q = DynamicBatcher::new(4, Duration::from_millis(50));
        let t0 = Instant::now();
        assert!(!q.should_flush(t0), "empty queue never flushes");

        // stamp enqueue times explicitly so the age math is exact
        let at = |task, t| BatchItem {
            enqueued: t,
            ..item(task, 0.0)
        };
        for i in 0..3 {
            q.push(at(i, t0));
        }
        assert!(!q.should_flush(t0), "below max_batch and fresh");
        q.push(at(3, t0));
        assert!(q.should_flush(t0), "max_batch reached");
        assert_eq!(q.take_batch().len(), 4);
        assert_eq!(q.pending(), 0);

        // age-based expiry: one lone item flushes once max_wait elapses
        q.push(at(9, t0));
        assert!(!q.should_flush(t0 + Duration::from_millis(10)));
        assert!(q.should_flush(t0 + Duration::from_millis(50)));
        let got = q.take_batch();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].task_id, 9);
    }

    #[test]
    fn take_batch_is_bounded_by_max_batch() {
        let mut q = DynamicBatcher::new(2, Duration::from_millis(1));
        for i in 0..5 {
            q.push(item(i, 0.0));
        }
        assert_eq!(q.take_batch().len(), 2);
        assert_eq!(q.pending(), 3);
    }

    #[test]
    fn wrong_length_image_fails_loudly_on_the_b8_path() {
        let (r, _b8, _b1) = runner(4);
        let mut bad = item(1, 1.0);
        bad.image.pop(); // 3 elements instead of 4
        let err = r.run(vec![item(0, 1.0), bad]).unwrap_err();
        assert!(format!("{err:#}").contains("expected 4"));
    }
}
