//! # macci — Multi-Agent Collaborative Inference (MAHPPO)
//!
//! Production-quality reproduction of *"Multi-Agent Collaborative Inference
//! via DNN Decoupling: Intermediate Feature Compression and Edge Learning"*
//! (Hao et al., 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the edge-server coordinator: the multi-UE MDP
//!   environment (wireless channel Eq. 5, task state machines, reward
//!   Eq. 12), the MAHPPO trainer (Sec. 5), baseline policies, the
//!   collaborative-inference serving path, and one experiment runner per
//!   paper figure.
//! * **L2 (python/compile, build-time only)** — JAX actor/critic networks,
//!   backbone CNNs, the autoencoder compressor; AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels (fused dense,
//!   1x1-conv channel mix, quantize/dequantize) that lower inside the L2
//!   HLO — with 1:1 Rust ports in [`runtime::native::kernels`].
//!
//! Execution is **pluggable** behind [`runtime::backend::Backend`]:
//!
//! * The default **native backend** interprets the actor/critic/
//!   autoencoder artifacts directly from their flat-f32 weights and
//!   manifest layouts in pure Rust — `cargo build && cargo test` and the
//!   quickstart run fully offline with zero generated files.
//! * The **PJRT backend** (cargo feature `xla-pjrt`, `MACCI_BACKEND=xla`)
//!   compiles the AOT `artifacts/*.hlo.txt` through the PJRT C API and is
//!   required for the CNN backbone segments. In the offline tree the `xla`
//!   dependency is an API-compatible stub; point it at the real crate to
//!   execute.
//!
//! ```no_run
//! use macci::prelude::*;
//!
//! let arts = ArtifactStore::open("artifacts")?; // native demo manifest if absent
//! let profile = DeviceProfile::load_or_synthetic("artifacts/profiles/resnet18.json")?;
//! let cfg = ScenarioConfig { n_ues: 5, ..Default::default() };
//! let mut trainer = MahppoTrainer::new(&arts, &profile, cfg, TrainConfig::default())?;
//! let report = trainer.train(2_000)?;
//! println!("final avg reward: {:.3}", report.final_reward());
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The offline build constraint (no crates.io) means common substrates are
//! implemented in-repo: [`util::json`], [`util::rng`], [`util::cli`],
//! [`util::bench`], [`util::check`], plus the vendored `anyhow`/`log`/
//! `once_cell` shims under `rust/vendor/` (see DESIGN.md §Substitutions).

pub mod compress;
pub mod coordinator;
pub mod env;
pub mod exp;
pub mod loadgen;
pub mod metrics;
pub mod profiles;
pub mod rl;
pub mod runtime;
pub mod transport;
pub mod util;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::compress::{huffman::HuffmanCoder, jalad::JaladCompressor, quant::Quantizer};
    pub use crate::coordinator::decision::{ActorDecision, DecisionMaker, PolicyHandle};
    pub use crate::coordinator::{inference::CollabPipeline, server::EdgeServer};
    pub use crate::env::{mdp::MultiAgentEnv, scenario::ScenarioConfig, Action, HybridAction};
    pub use crate::profiles::DeviceProfile;
    pub use crate::rl::baselines::{BaselinePolicy, PolicyKind};
    pub use crate::rl::checkpoint::{PolicySnapshot, TrainerCheckpoint};
    pub use crate::rl::mahppo::{MahppoTrainer, TrainConfig, TrainReport};
    pub use crate::runtime::backend::{Backend, Executable, Precision};
    pub use crate::runtime::native::NativeBackend;
    pub use crate::runtime::{artifacts::ArtifactStore, tensor::TensorView};
    pub use crate::transport::tcp::{TcpClientTransport, TcpServerTransport};
    pub use crate::transport::ue::UeClient;
    pub use crate::util::rng::Rng;
}
