//! # macci — Multi-Agent Collaborative Inference (MAHPPO)
//!
//! Production-quality reproduction of *"Multi-Agent Collaborative Inference
//! via DNN Decoupling: Intermediate Feature Compression and Edge Learning"*
//! (Hao et al., 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the edge-server coordinator: the multi-UE MDP
//!   environment (wireless channel Eq. 5, task state machines, reward
//!   Eq. 12), the MAHPPO trainer (Sec. 5), baseline policies, the
//!   collaborative-inference serving path, and one experiment runner per
//!   paper figure.
//! * **L2 (python/compile, build-time only)** — JAX actor/critic networks,
//!   backbone CNNs, the autoencoder compressor; AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels (fused dense,
//!   1x1-conv channel mix, quantize/dequantize) that lower inside the L2
//!   HLO.
//!
//! Python never runs at inference or training time: the [`runtime`] module
//! loads `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and all
//! hot paths are pure Rust + compiled XLA executables.
//!
//! ```no_run
//! use macci::prelude::*;
//!
//! let arts = ArtifactStore::open("artifacts")?;
//! let profile = DeviceProfile::load("artifacts/profiles/resnet18.json")?;
//! let cfg = ScenarioConfig { n_ues: 5, ..Default::default() };
//! let mut trainer = MahppoTrainer::new(&arts, &profile, cfg, TrainConfig::default())?;
//! let report = trainer.train(2_000)?;
//! println!("final avg reward: {:.3}", report.final_reward());
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The offline build constraint (no crates.io) means common substrates are
//! implemented in-repo: [`util::json`], [`util::rng`], [`util::cli`],
//! [`util::bench`], [`util::check`].

pub mod compress;
pub mod coordinator;
pub mod env;
pub mod exp;
pub mod metrics;
pub mod profiles;
pub mod rl;
pub mod runtime;
pub mod util;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::compress::{huffman::HuffmanCoder, jalad::JaladCompressor, quant::Quantizer};
    pub use crate::coordinator::{inference::CollabPipeline, server::EdgeServer};
    pub use crate::env::{mdp::MultiAgentEnv, scenario::ScenarioConfig, Action, HybridAction};
    pub use crate::profiles::DeviceProfile;
    pub use crate::rl::baselines::{BaselinePolicy, PolicyKind};
    pub use crate::rl::mahppo::{MahppoTrainer, TrainConfig, TrainReport};
    pub use crate::runtime::{artifacts::ArtifactStore, client::Runtime};
    pub use crate::util::rng::Rng;
}


