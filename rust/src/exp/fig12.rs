//! Fig. 12 — impact of the β hyperparameter (Eq. 10/12): larger β weights
//! energy more heavily, trading inference latency for energy savings.
//! N = 5; each β is trained with `seeds` independent runs; mean ± std of
//! the evaluated latency/energy are reported (the paper's shaded belts).

use anyhow::Result;

use super::common::{ExpContext, Table};
use crate::metrics::{Report, Series};
use crate::rl::mahppo::TrainConfig;
use crate::util::stats;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let profile = ctx.profile("resnet18")?;
    let betas: Vec<f64> = if ctx.quick {
        vec![0.1, 10.0]
    } else {
        vec![0.01, 0.1, 1.0, 10.0, 100.0, 1000.0]
    };

    let mut table = Table::new(&["beta", "latency ms (±std)", "energy mJ (±std)"]);
    let mut report = Report::new("Fig. 12 — beta trade-off (N=5)");
    let mut s_lat = Series::new("latency_ms");
    let mut s_lat_std = Series::new("latency_ms_std");
    let mut s_en = Series::new("energy_mj");
    let mut s_en_std = Series::new("energy_mj_std");

    for &beta in &betas {
        let mut lats = Vec::new();
        let mut ens = Vec::new();
        for s in 0..ctx.seeds {
            let mut scenario = ctx.scenario(5);
            scenario.beta = beta;
            let cfg = TrainConfig {
                seed: 100 + s as u64 * 7919,
                ..ctx.train_config()
            };
            let (_r, stats) = ctx.train_and_eval(&profile, scenario, cfg)?;
            lats.push(stats.avg_latency * 1e3);
            ens.push(stats.avg_energy * 1e3);
        }
        let (lm, ls) = (stats::mean(&lats), stats::std(&lats));
        let (em, es) = (stats::mean(&ens), stats::std(&ens));
        println!("[fig12] beta {beta:>7}: t = {lm:.1} ± {ls:.1} ms, e = {em:.1} ± {es:.1} mJ");
        table.row(vec![
            format!("{beta}"),
            format!("{lm:.1} ± {ls:.1}"),
            format!("{em:.1} ± {es:.1}"),
        ]);
        let x = beta.log10();
        s_lat.push(x, lm);
        s_lat_std.push(x, ls);
        s_en.push(x, em);
        s_en_std.push(x, es);
    }

    println!("\nFig. 12: beta sweep (x-axis log10(beta))");
    table.print();
    // shape check: latency should rise and energy fall as beta grows
    let lat_up = s_lat.ys.last().unwrap_or(&0.0) >= s_lat.ys.first().unwrap_or(&0.0);
    let en_down = s_en.ys.last().unwrap_or(&0.0) <= s_en.ys.first().unwrap_or(&0.0);
    println!("shape: latency non-decreasing in beta: {lat_up}, energy non-increasing: {en_down}");

    report.add_series(s_lat);
    report.add_series(s_lat_std);
    report.add_series(s_en);
    report.add_series(s_en_std);
    report.write(&ctx.results_dir, "fig12")?;
    Ok(())
}
