//! Fig. 5 — impact of the ξ balancing hyperparameter in the AE loss
//! (Eq. 4) on task accuracy, per partition point.
//!
//! The sweep itself runs at build time (trainer.py ξ-sweep, recorded in
//! artifacts/compression/resnet18.json); this runner renders the table and
//! checks the paper's conclusion (ξ = 0.1 best or near-best everywhere).

use anyhow::Result;

use super::common::{ExpContext, Table};
use crate::metrics::{Report, Series};

pub fn run(ctx: &ExpContext) -> Result<()> {
    let summary = ctx.compression_summary("resnet18")?;
    let sweep = summary.req("xi_sweep")?.as_arr()?;
    if sweep.is_empty() {
        println!("Fig. 5: no ξ sweep in artifacts (trainer ran without --with-xi)");
        return Ok(());
    }

    let mut xis: Vec<f64> = Vec::new();
    for e in sweep {
        let x = e.f64_of("xi")?;
        if !xis.contains(&x) {
            xis.push(x);
        }
    }

    let mut table = Table::new(&["point", "xi", "accuracy"]);
    let mut report = Report::new("Fig. 5 — xi settings vs accuracy");
    let mut by_xi: Vec<(f64, Series)> = xis
        .iter()
        .map(|&x| (x, Series::new(format!("xi_{x}"))))
        .collect();

    let mut best_count_01 = 0usize;
    for point in 1..=4usize {
        let mut best = (f64::NEG_INFINITY, -1.0);
        for e in sweep {
            if e.usize_of("point")? != point {
                continue;
            }
            let xi = e.f64_of("xi")?;
            let acc = e.f64_of("acc")?;
            table.row(vec![
                format!("p{point}"),
                format!("{xi}"),
                format!("{acc:.3}"),
            ]);
            if let Some((_, s)) = by_xi.iter_mut().find(|(x, _)| *x == xi) {
                s.push(point as f64, acc);
            }
            if acc > best.0 {
                best = (acc, xi);
            }
        }
        // count points where xi = 0.1 is within 1% of the best
        if let Some(e) = sweep.iter().find(|e| {
            e.usize_of("point").ok() == Some(point) && e.f64_of("xi").ok() == Some(0.1)
        }) {
            if e.f64_of("acc")? >= best.0 - 0.01 {
                best_count_01 += 1;
            }
        }
    }

    println!("Fig. 5 (resnet18): accuracy per xi setting");
    table.print();
    println!("xi = 0.1 within 1% of best at {best_count_01}/4 points (paper: best or near-best everywhere)");

    for (_, s) in by_xi {
        report.add_series(s);
    }
    report.fact("xi01_near_best_points", best_count_01 as f64);
    report.write(&ctx.results_dir, "fig5")?;
    Ok(())
}
