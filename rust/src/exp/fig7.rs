//! Fig. 7 — latency and energy of executing the front segment + feature
//! compression on the UE, per partition point, vs full-local inference.
//!
//! Rendered from the analytic device profile (the Jetson-Nano substitute,
//! see DESIGN.md §Substitutions), including the JALAD comparison the paper
//! discusses (entropy coding making most cuts worse than full local).

use anyhow::Result;

use super::common::{fmt_mj, fmt_ms, ExpContext, Table};
use crate::metrics::{Report, Series};

pub fn run(ctx: &ExpContext) -> Result<()> {
    run_for_model(ctx, "resnet18", "fig7")
}

pub fn run_for_model(ctx: &ExpContext, model: &str, slug: &str) -> Result<()> {
    let profile = ctx.profile(model)?;
    let jalad = profile.jalad_variant();

    let mut table = Table::new(&[
        "decision",
        "t_f (ms)",
        "t_c (ms)",
        "t total",
        "e_f (mJ)",
        "e_c (mJ)",
        "e total",
        "JALAD t_c",
        "JALAD e total",
    ]);
    let mut lat = Series::new("latency_ms");
    let mut en = Series::new("energy_mj");
    let mut jalad_en = Series::new("jalad_energy_mj");

    for b in 1..profile.n_choices - 1 {
        let e = profile.entry(b);
        let je = jalad.entry(b);
        let t_tot = e.t_f + e.t_c;
        let e_tot = e.e_f + e.e_c;
        lat.push(b as f64, t_tot * 1e3);
        en.push(b as f64, e_tot * 1e3);
        jalad_en.push(b as f64, (je.e_f + je.e_c) * 1e3);
        table.row(vec![
            format!("p{b}"),
            fmt_ms(e.t_f),
            fmt_ms(e.t_c),
            fmt_ms(t_tot),
            fmt_mj(e.e_f),
            fmt_mj(e.e_c),
            fmt_mj(e_tot),
            fmt_ms(je.t_c),
            fmt_mj(je.e_f + je.e_c),
        ]);
    }
    table.row(vec![
        "full local".into(),
        fmt_ms(profile.full_local_t),
        "0.0".into(),
        fmt_ms(profile.full_local_t),
        fmt_mj(profile.full_local_e),
        "0.0".into(),
        fmt_mj(profile.full_local_e),
        "-".into(),
        "-".into(),
    ]);

    println!("Fig. 7 ({model}): UE-side overhead per partition point (gray line = full local)");
    table.print();

    // the paper's observations:
    let cuts_below_local = (1..profile.n_choices - 1)
        .filter(|&b| {
            let e = profile.entry(b);
            e.t_f + e.t_c < profile.full_local_t
        })
        .count();
    let last = profile.entry(profile.n_choices - 2);
    println!(
        "latency below full-local at {cuts_below_local}/{} cuts; energy at last cut \
         {} full-local ({} vs {} mJ) — paper: exceeds it",
        profile.n_choices - 2,
        if last.e_f + last.e_c > profile.full_local_e { "EXCEEDS" } else { "below" },
        fmt_mj(last.e_f + last.e_c),
        fmt_mj(profile.full_local_e),
    );

    let mut report = Report::new(format!("Fig. 7 — local overhead ({model})"));
    report.fact("full_local_ms", profile.full_local_t * 1e3);
    report.fact("full_local_mj", profile.full_local_e * 1e3);
    report.fact("cuts_below_local", cuts_below_local as f64);
    report.add_series(lat);
    report.add_series(en);
    report.add_series(jalad_en);
    report.write(&ctx.results_dir, slug)?;
    Ok(())
}
