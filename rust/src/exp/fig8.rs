//! Fig. 8 — convergence of MAHPPO vs the Local and JALAD baselines
//! (ResNet18, N = 5).
//!
//! * MAHPPO: trained on the AE-compressor profile, T0 = 0.5 s.
//! * Local: the full-local policy's episode-reward trace (no learning).
//! * JALAD: the same MAHPPO agent trained on the JALAD-compressor profile
//!   with the paper's relaxed T0 = 3 s frame (Sec. 6.3.1) — its cumulative
//!   reward is shrunk ~6x by the longer frames, exactly as the paper
//!   discusses.

use anyhow::Result;

use super::common::{mean_curve, ExpContext};
use crate::env::mdp::MultiAgentEnv;
use crate::metrics::{Report, Series};
use crate::rl::baselines::{reward_trace, BaselinePolicy, PolicyKind};
use crate::util::stats;

pub fn run(ctx: &ExpContext) -> Result<()> {
    run_for_model(ctx, "resnet18", "fig8")
}

pub fn run_for_model(ctx: &ExpContext, model: &str, slug: &str) -> Result<()> {
    let profile = ctx.profile(model)?;
    let scenario = ctx.scenario(5);

    println!("[fig8] training MAHPPO ({model}, N=5, {} frames x {} seeds)", ctx.frames, ctx.seeds);
    let mahppo = ctx.train_seeds(&profile, &scenario, ctx.train_config())?;
    let mahppo_curve = mean_curve("mahppo", &mahppo);

    println!("[fig8] training JALAD variant (T0 = 3 s)");
    let jalad_profile = profile.jalad_variant();
    let jalad_scenario = scenario.clone().jalad_frame();
    let jalad = ctx.train_seeds(&jalad_profile, &jalad_scenario, ctx.train_config())?;
    let jalad_curve = mean_curve("jalad", &jalad);

    // Local baseline: flat trace over the same number of episodes
    let episodes = mahppo_curve.ys.len().max(8);
    let mut env = MultiAgentEnv::new(profile.clone(), scenario.clone(), 999)?;
    let mut local = BaselinePolicy::new(PolicyKind::Local, 0);
    let trace = reward_trace(&mut local, &mut env, episodes.min(40))?;
    let mut local_curve = Series::new("local");
    let local_mean = stats::mean(&trace);
    for i in 0..episodes {
        local_curve.push(i as f64, trace.get(i).copied().unwrap_or(local_mean));
    }

    let m_final = mahppo_curve.tail_mean(10);
    let l_final = local_curve.tail_mean(10);
    let j_final = jalad_curve.tail_mean(10);
    println!("\nFig. 8 convergence (cumulative episode reward, higher is better):");
    println!("  MAHPPO  final ~ {m_final:9.2}");
    println!("  Local   final ~ {l_final:9.2}");
    println!("  JALAD   final ~ {j_final:9.2}  (x6 frame shrinkage: ~{:9.2} comparable)", j_final * 6.0);
    println!(
        "ordering check: MAHPPO > Local: {} | MAHPPO > JALAD: {}",
        m_final > l_final,
        m_final > j_final
    );

    let mut report = Report::new(format!("Fig. 8 — convergence ({model}, N=5)"));
    report.fact("mahppo_final", m_final);
    report.fact("local_final", l_final);
    report.fact("jalad_final", j_final);
    report.add_series(mahppo_curve);
    report.add_series(local_curve);
    report.add_series(jalad_curve);
    report.write(&ctx.results_dir, slug)?;
    Ok(())
}
