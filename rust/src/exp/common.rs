//! Shared experiment plumbing: context (artifact store, profiles, budgets),
//! training helpers and table printing.

use anyhow::{anyhow, Result};

use crate::env::scenario::ScenarioConfig;
use crate::metrics::Series;
use crate::profiles::DeviceProfile;
use crate::rl::mahppo::{EvalStats, MahppoTrainer, TrainConfig, TrainReport};
use crate::runtime::artifacts::ArtifactStore;
use crate::util::json::Json;
use crate::util::stats;

/// Everything a figure runner needs.
pub struct ExpContext {
    pub store: ArtifactStore,
    pub results_dir: String,
    /// Training frames per run (figures scale this).
    pub frames: usize,
    /// Independent seeds per configuration (paper: 5).
    pub seeds: usize,
    /// Episodes per evaluation.
    pub eval_episodes: usize,
    /// Poisson task-count parameter (paper: 200; smaller = faster runs).
    pub lambda_tasks: f64,
    /// Rollout lanes per trainer (`TrainConfig::n_envs`); override with
    /// MACCI_N_ENVS. 1 reproduces the pre-vectorization serial runs.
    pub n_envs: usize,
    /// Quick mode: tiny budgets for smoke-testing the full harness.
    pub quick: bool,
}

impl ExpContext {
    pub fn new(store: ArtifactStore, quick: bool) -> ExpContext {
        let n_envs = crate::util::config::n_envs(1);
        if quick {
            ExpContext {
                store,
                results_dir: "results".into(),
                frames: 600,
                seeds: 1,
                eval_episodes: 1,
                lambda_tasks: 40.0,
                n_envs,
                quick,
            }
        } else {
            ExpContext {
                store,
                results_dir: "results".into(),
                frames: 6_000,
                seeds: 2,
                eval_episodes: 3,
                lambda_tasks: 200.0,
                n_envs,
                quick,
            }
        }
    }

    /// The figure runners' base training config: defaults plus this
    /// context's rollout lane count.
    pub fn train_config(&self) -> TrainConfig {
        TrainConfig {
            n_envs: self.n_envs,
            ..Default::default()
        }
    }

    /// Load the paper-scale device profile for a model.
    pub fn profile(&self, model: &str) -> Result<DeviceProfile> {
        let path = self.store.root.join("profiles").join(format!("{model}.json"));
        DeviceProfile::load(&path)
            .map_err(|e| anyhow!("profile for {model} ({}): {e:#}", path.display()))
    }

    /// Compression summary JSON written by the build-time trainer.
    pub fn compression_summary(&self, model: &str) -> Result<Json> {
        Json::parse_file(
            self.store
                .root
                .join("compression")
                .join(format!("{model}.json")),
        )
    }

    /// The default training scenario for a figure run.
    pub fn scenario(&self, n_ues: usize) -> ScenarioConfig {
        ScenarioConfig {
            n_ues,
            lambda_tasks: self.lambda_tasks,
            eval_tasks: self.lambda_tasks as u64,
            ..Default::default()
        }
    }

    /// Train one MAHPPO agent; returns the trainer (for evaluation) and its
    /// report (for curves).
    pub fn train_agent(
        &self,
        profile: &DeviceProfile,
        mut scenario: ScenarioConfig,
        cfg: TrainConfig,
    ) -> Result<(MahppoTrainer, TrainReport)> {
        scenario.lambda_tasks = self.lambda_tasks;
        let mut t = MahppoTrainer::new(&self.store, profile, scenario, cfg)?;
        let report = t.train(self.frames)?;
        Ok((t, report))
    }

    /// Train with several seeds, returning per-seed reports.
    pub fn train_seeds(
        &self,
        profile: &DeviceProfile,
        scenario: &ScenarioConfig,
        base: TrainConfig,
    ) -> Result<Vec<TrainReport>> {
        (0..self.seeds)
            .map(|s| {
                let cfg = TrainConfig {
                    seed: base.seed + s as u64 * 7919,
                    ..base.clone()
                };
                let (_t, r) = self.train_agent(profile, scenario.clone(), cfg)?;
                Ok(r)
            })
            .collect()
    }

    /// Train, then greedy-evaluate in eval mode (d = 50, K fixed). The
    /// evaluation runs on a fresh eval-seeded env, so it cannot perturb
    /// the trainer's streams.
    pub fn train_and_eval(
        &self,
        profile: &DeviceProfile,
        scenario: ScenarioConfig,
        cfg: TrainConfig,
    ) -> Result<(TrainReport, EvalStats)> {
        let (mut t, report) = self.train_agent(profile, scenario.clone(), cfg)?;
        let mut eval_sc = scenario;
        eval_sc.eval_mode = true;
        eval_sc.lambda_tasks = self.lambda_tasks;
        eval_sc.eval_tasks = self.lambda_tasks as u64;
        let stats = t.evaluate_on(eval_sc, self.eval_episodes)?;
        Ok((report, stats))
    }
}

/// Average several per-episode reward curves into one mean series (curves
/// may have different lengths; we truncate to the shortest).
pub fn mean_curve(name: &str, reports: &[TrainReport]) -> Series {
    let min_len = reports
        .iter()
        .map(|r| r.episode_rewards.ys.len())
        .min()
        .unwrap_or(0);
    let mut s = Series::new(name);
    for i in 0..min_len {
        let vals: Vec<f64> = reports.iter().map(|r| r.episode_rewards.ys[i]).collect();
        s.push(i as f64, stats::mean(&vals));
    }
    s.smoothed(5)
}

/// Fixed-width table printer for figure output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut out = String::new();
            for (i, c) in cells.iter().enumerate().take(ncol) {
                out.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", out.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        for r in &self.rows {
            line(r);
        }
    }
}

pub fn fmt_ms(s: f64) -> String {
    format!("{:.1}", s * 1e3)
}

pub fn fmt_mj(j: f64) -> String {
    format!("{:.1}", j * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_curve_truncates_and_averages() {
        let mut r1 = TrainReport::default();
        let mut r2 = TrainReport::default();
        for i in 0..5 {
            r1.episode_rewards.push(i as f64, 1.0);
        }
        for i in 0..3 {
            r2.episode_rewards.push(i as f64, 3.0);
        }
        let m = mean_curve("m", &[r1, r2]);
        assert_eq!(m.ys.len(), 3);
        assert!((m.ys[0] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }
}
