//! Fig. 9 — hyperparameter sensitivity of MAHPPO (N = 5, ResNet18):
//! (a) learning rate, (b) sample reuse time K, (c) memory size ‖M‖ reward,
//! (d) memory size value loss. Batch size follows ‖M‖/4 as in common PPO
//! implementations (the AOT artifacts ship B ∈ {128, 256, 512} for N = 5).

use anyhow::Result;

use super::common::{mean_curve, ExpContext};
use crate::metrics::{Report, Series};
use crate::rl::mahppo::TrainConfig;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let profile = ctx.profile("resnet18")?;
    let scenario = ctx.scenario(5);
    let mut report = Report::new("Fig. 9 — hyperparameter sweeps (N=5)");

    // (a) learning rate
    println!("[fig9a] learning-rate sweep");
    for lr in [1e-3f32, 1e-4, 1e-5] {
        let cfg = TrainConfig { lr, ..ctx.train_config() };
        let runs = ctx.train_seeds(&profile, &scenario, cfg)?;
        let mut curve = mean_curve(&format!("lr_{lr:e}"), &runs);
        curve.name = format!("lr_{lr:e}");
        println!("  lr {lr:>7e}: final reward {:9.2}", curve.tail_mean(10));
        report.add_series(curve);
    }

    // (b) sample reuse time
    println!("[fig9b] sample-reuse sweep");
    for reuse in [1usize, 5, 20, 80] {
        let cfg = TrainConfig { reuse, ..ctx.train_config() };
        let runs = ctx.train_seeds(&profile, &scenario, cfg)?;
        let curve = {
            let mut c = mean_curve(&format!("reuse_{reuse}"), &runs);
            c.name = format!("reuse_{reuse}");
            c
        };
        println!("  K = {reuse:>2}: final reward {:9.2}", curve.tail_mean(10));
        report.add_series(curve);
    }

    // (c)+(d) memory size (batch = |M|/4)
    println!("[fig9cd] memory-size sweep");
    for mem in [512usize, 1024, 2048] {
        let cfg = TrainConfig {
            buffer_size: mem,
            minibatch: mem / 4,
            ..ctx.train_config()
        };
        let runs = ctx.train_seeds(&profile, &scenario, cfg)?;
        let mut reward = mean_curve(&format!("mem_{mem}"), &runs);
        reward.name = format!("mem_{mem}_reward");
        // value loss: average the per-round loss series across seeds
        let mut vloss = Series::new(format!("mem_{mem}_value_loss"));
        let min_len = runs
            .iter()
            .map(|r| r.value_losses.ys.len())
            .min()
            .unwrap_or(0);
        for i in 0..min_len {
            let mean: f64 = runs.iter().map(|r| r.value_losses.ys[i]).sum::<f64>()
                / runs.len() as f64;
            vloss.push(runs[0].value_losses.xs[i], mean);
        }
        println!(
            "  |M| = {mem:>4}: final reward {:9.2}, last value loss {:.4}",
            reward.tail_mean(10),
            vloss.last().unwrap_or(f64::NAN)
        );
        report.add_series(reward);
        report.add_series(vloss);
    }

    report.write(&ctx.results_dir, "fig9")?;
    println!("fig9 series written to results/fig9.{{json,csv}}");
    Ok(())
}
