//! Fig. 11 — averaged per-task inference latency and energy across UE
//! counts, MAHPPO vs Local vs JALAD, plus the paper's headline numbers:
//! at N = 3, MAHPPO cuts up to 56% of latency and 72% of energy vs the
//! full-local strategy.
//!
//! Each MAHPPO/JALAD point trains an agent at that N and then greedy-
//! evaluates it in eval mode (d = 50 m, fixed task count).

use anyhow::Result;

use super::common::{fmt_mj, fmt_ms, ExpContext, Table};
use crate::env::mdp::MultiAgentEnv;
use crate::metrics::{Report, Series};
use crate::rl::baselines::{evaluate_policy, BaselinePolicy, PolicyKind};

pub fn run(ctx: &ExpContext) -> Result<()> {
    let ns: Vec<usize> = if ctx.quick { vec![3, 5] } else { vec![3, 4, 5, 6, 8, 10] };
    run_for_model(ctx, "resnet18", "fig11", &ns)
}

pub fn run_for_model(ctx: &ExpContext, model: &str, slug: &str, ns: &[usize]) -> Result<()> {
    let profile = ctx.profile(model)?;

    let mut table = Table::new(&[
        "N",
        "MAHPPO t (ms)",
        "Local t",
        "JALAD t",
        "MAHPPO e (mJ)",
        "Local e",
        "JALAD e",
        "t saved",
        "e saved",
    ]);
    let mut report = Report::new(format!("Fig. 11 — averaged inference overhead ({model})"));
    let mut s_lat = Series::new("mahppo_latency_ms");
    let mut s_en = Series::new("mahppo_energy_mj");
    let mut s_lat_local = Series::new("local_latency_ms");
    let mut s_en_local = Series::new("local_energy_mj");
    let mut s_lat_jalad = Series::new("jalad_latency_ms");
    let mut s_en_jalad = Series::new("jalad_energy_mj");
    let mut headline: Option<(f64, f64)> = None;

    for &n in ns {
        println!("[fig11] N = {n}: training + evaluating MAHPPO");
        let (_report, ours) =
            ctx.train_and_eval(&profile, ctx.scenario(n), ctx.train_config())?;

        println!("[fig11] N = {n}: training + evaluating JALAD variant");
        let jalad_profile = profile.jalad_variant();
        let (_jr, jalad) = ctx.train_and_eval(
            &jalad_profile,
            ctx.scenario(n).jalad_frame(),
            ctx.train_config(),
        )?;

        // Local baseline needs no training
        let mut env = MultiAgentEnv::new(
            profile.clone(),
            {
                let mut s = ctx.scenario(n);
                s.eval_mode = true;
                s.eval_tasks = ctx.lambda_tasks as u64;
                s
            },
            7,
        )?;
        let mut local = BaselinePolicy::new(PolicyKind::Local, 0);
        let loc = evaluate_policy(&mut local, &mut env, ctx.eval_episodes)?;

        let t_saved = 1.0 - ours.avg_latency / loc.avg_latency.max(1e-12);
        let e_saved = 1.0 - ours.avg_energy / loc.avg_energy.max(1e-12);
        if n == 3 {
            headline = Some((t_saved, e_saved));
        }

        s_lat.push(n as f64, ours.avg_latency * 1e3);
        s_en.push(n as f64, ours.avg_energy * 1e3);
        s_lat_local.push(n as f64, loc.avg_latency * 1e3);
        s_en_local.push(n as f64, loc.avg_energy * 1e3);
        s_lat_jalad.push(n as f64, jalad.avg_latency * 1e3);
        s_en_jalad.push(n as f64, jalad.avg_energy * 1e3);

        table.row(vec![
            n.to_string(),
            fmt_ms(ours.avg_latency),
            fmt_ms(loc.avg_latency),
            fmt_ms(jalad.avg_latency),
            fmt_mj(ours.avg_energy),
            fmt_mj(loc.avg_energy),
            fmt_mj(jalad.avg_energy),
            format!("{:.0}%", t_saved * 100.0),
            format!("{:.0}%", e_saved * 100.0),
        ]);
    }

    println!("\nFig. 11 ({model}): averaged per-task inference overhead");
    table.print();
    if let Some((t, e)) = headline {
        println!(
            "\nHEADLINE @ N=3: latency saved {:.0}% (paper: up to 56%), energy saved {:.0}% (paper: up to 72%)",
            t * 100.0,
            e * 100.0
        );
        report.fact("headline_latency_saved", t);
        report.fact("headline_energy_saved", e);
    }

    report.add_series(s_lat);
    report.add_series(s_en);
    report.add_series(s_lat_local);
    report.add_series(s_en_local);
    report.add_series(s_lat_jalad);
    report.add_series(s_en_jalad);
    report.write(&ctx.results_dir, slug)?;
    Ok(())
}
