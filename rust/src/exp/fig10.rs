//! Fig. 10 — convergence across UE counts N = 3…10 with C = 2 channels
//! fixed. More UEs ⇒ more interference ⇒ slower convergence and a lower
//! convergent reward (fixed channel resources).

use anyhow::Result;

use super::common::{mean_curve, ExpContext};
use crate::metrics::Report;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let ns: Vec<usize> = if ctx.quick { vec![3, 5] } else { (3..=10).collect() };
    run_for_model(ctx, "resnet18", "fig10", &ns)
}

pub fn run_for_model(ctx: &ExpContext, model: &str, slug: &str, ns: &[usize]) -> Result<()> {
    let profile = ctx.profile(model)?;

    let mut report = Report::new(format!("Fig. 10 — convergence per UE count ({model})"));
    let mut finals = Vec::new();
    for &n in ns {
        println!("[fig10] training N = {n}");
        let scenario = ctx.scenario(n);
        let runs = ctx.train_seeds(&profile, &scenario, ctx.train_config())?;
        let mut curve = mean_curve(&format!("n{n}"), &runs);
        curve.name = format!("n{n}");
        let f = curve.tail_mean(10);
        println!("  N = {n}: final reward {f:9.2} over {} episodes", curve.ys.len());
        finals.push((n, f));
        report.add_series(curve);
    }

    // paper check: convergent value tends to decrease with N
    let decreasing_pairs = finals
        .windows(2)
        .filter(|w| w[1].1 <= w[0].1 + 0.05 * w[0].1.abs())
        .count();
    println!(
        "\nfinal-reward trend: {}/{} adjacent N pairs non-increasing (paper: larger N converges lower)",
        decreasing_pairs,
        finals.len().saturating_sub(1)
    );
    for (n, f) in &finals {
        report.fact(format!("final_n{n}"), *f);
    }
    report.write(&ctx.results_dir, slug)?;
    Ok(())
}
