//! Fig. 13 — the whole evaluation replicated on VGG11 and MobileNetV2:
//! (a,b) compression rates, (c,d) convergence per UE count, (e,f) averaged
//! inference overhead per UE count. Reuses the fig4/fig10/fig11 runners
//! parameterized by model.

use anyhow::Result;

use super::common::ExpContext;
use super::{fig10, fig11, fig4, fig7};

pub fn run(ctx: &ExpContext) -> Result<()> {
    for model in ["vgg11", "mobilenetv2"] {
        if ctx.store.model(model).is_err() {
            println!("[fig13] skipping {model}: not in artifacts (run `make artifacts-models`)");
            continue;
        }
        println!("\n--- Fig. 13: {model} ---");
        // lighter N grids than the resnet18 figures — fig13 covers 2 models
        let ns: Vec<usize> = if ctx.quick { vec![3] } else { vec![3, 5, 8, 10] };
        fig4::run_for_model(ctx, model, &format!("fig13_{model}_compression"))?;
        fig7::run_for_model(ctx, model, &format!("fig13_{model}_overhead_points"))?;
        fig10::run_for_model(ctx, model, &format!("fig13_{model}_convergence"), &ns)?;
        fig11::run_for_model(ctx, model, &format!("fig13_{model}_overhead"), &ns)?;
    }
    Ok(())
}
