//! Fig. 4 — compression-rate comparison of the lightweight autoencoder vs
//! JALAD at the four ResNet18 partition points.
//!
//! AE rates come from the build-time sweep (max rate under the 2% accuracy
//! bound, artifacts/compression/resnet18.json). JALAD rates are *measured
//! live*: real intermediate features are produced by the AOT front-segment
//! executables on synthetic inputs and pushed through the 8-bit-quant +
//! Huffman pipeline (compress/jalad.rs).

use anyhow::Result;

use super::common::{ExpContext, Table};
use crate::compress::jalad::JaladCompressor;
use crate::coordinator::inference::CollabPipeline;
use crate::metrics::{Report, Series};
use crate::util::rng::Rng;

/// Smooth pseudo-image batch (low-frequency noise) — stands in for dataset
/// samples when measuring feature statistics in Rust.
pub fn smooth_images(n: usize, hw: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            // upsampled 4x4 random field + noise, like the python dataset
            let mut low = [[0.0f32; 4]; 4];
            let mut img = vec![0.0f32; 3 * hw * hw];
            for c in 0..3 {
                for cell in low.iter_mut().flatten() {
                    *cell = rng.normal() as f32;
                }
                for y in 0..hw {
                    for x in 0..hw {
                        let v = low[y * 4 / hw][x * 4 / hw] + 0.25 * rng.normal() as f32;
                        img[c * hw * hw + y * hw + x] = v;
                    }
                }
            }
            img
        })
        .collect()
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    run_for_model(ctx, "resnet18", "fig4")
}

pub fn run_for_model(ctx: &ExpContext, model: &str, slug: &str) -> Result<()> {
    let summary = ctx.compression_summary(model)?;
    let pipeline = CollabPipeline::load(&ctx.store, model)?;
    let jalad = JaladCompressor::new();
    let images = smooth_images(if ctx.quick { 2 } else { 8 }, pipeline.meta.input_hw, 42);

    let mut table = Table::new(&["point", "AE rate (ours)", "JALAD rate", "AE acc drop"]);
    let mut ae_series = Series::new("ae_rate");
    let mut jalad_series = Series::new("jalad_rate");
    let mut report = Report::new("Fig. 4 — intermediate feature compression rate");

    for (i, p) in summary.req("points")?.as_arr()?.iter().enumerate() {
        let point = p.usize_of("point")?;
        let chosen = p.req("chosen")?;
        let ae_rate = chosen.f64_of("rate")?;
        let acc_drop = chosen.f64_of("acc_drop")?;

        // measure JALAD on real features from the front segment
        let mut jr = 0.0;
        for img in &images {
            let feature = pipeline.front_feature(img, point)?;
            jr += jalad.rate(&feature);
        }
        jr /= images.len() as f64;

        ae_series.push(point as f64, ae_rate);
        jalad_series.push(point as f64, jr);
        table.row(vec![
            format!("p{point}"),
            format!("{ae_rate:.1}x"),
            format!("{jr:.1}x"),
            format!("{:+.3}", acc_drop),
        ]);
        let _ = i;
    }

    println!("Fig. 4 ({model}): compression rate, AE (ours) vs JALAD");
    table.print();
    let ae_first = ae_series.ys.first().copied().unwrap_or(0.0);
    let ja_first = jalad_series.ys.first().copied().unwrap_or(1.0);
    println!(
        "shape check: AE beats JALAD at p1 ({:.1}x vs {:.1}x) and decays with depth: {}",
        ae_first,
        ja_first,
        ae_series.ys.windows(2).all(|w| w[1] <= w[0] + 1e-9)
    );

    report.add_series(ae_series);
    report.add_series(jalad_series);
    report.fact("base_acc", summary.f64_of("base_acc")?);
    report.write(&ctx.results_dir, slug)?;
    Ok(())
}
