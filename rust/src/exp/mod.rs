//! Experiment harness: one runner per figure of the paper's evaluation
//! (Sec. 6). Each runner regenerates the corresponding rows/series, prints
//! them as a table and writes `results/<fig>.json` + `.csv`.
//!
//! | runner | paper figure |
//! |--------|--------------|
//! | [`fig4`]  | AE vs JALAD compression rate, ResNet18 |
//! | [`fig5`]  | ξ settings vs accuracy |
//! | [`fig7`]  | per-point local latency/energy overhead |
//! | [`fig8`]  | MAHPPO vs Local vs JALAD convergence |
//! | [`fig9`]  | lr / sample-reuse / memory-size sweeps |
//! | [`fig10`] | convergence across UE counts |
//! | [`fig11`] | avg inference overhead across UE counts (+ headline) |
//! | [`fig12`] | β sweep latency/energy trade-off |
//! | [`fig13`] | VGG11 + MobileNetV2 replications |

pub mod common;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig4;
pub mod fig5;
pub mod fig7;
pub mod fig8;
pub mod fig9;

use anyhow::{bail, Result};

use common::ExpContext;

/// Dispatch an experiment by name ("fig4" … "fig13", "headline", "all").
pub fn run(name: &str, ctx: &ExpContext) -> Result<()> {
    match name {
        "fig4" => fig4::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "fig9" => fig9::run(ctx),
        "fig10" => fig10::run(ctx),
        "fig11" | "headline" => fig11::run(ctx),
        "fig12" => fig12::run(ctx),
        "fig13" => fig13::run(ctx),
        "all" => {
            for f in [
                "fig4", "fig5", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13",
            ] {
                println!("\n================ {f} ================");
                run(f, ctx)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' (try fig4..fig13, headline, all)"),
    }
}
