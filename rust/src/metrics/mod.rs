//! Metrics recording: training curves, per-frame diagnostics, CSV/JSON
//! emission for the experiment harness (every figure writes through here).

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::stats;

/// A named series of (x, y) points — one curve on a paper figure.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub name: String,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Series {
        Series {
            name: name.into(),
            ..Default::default()
        }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.xs.push(x);
        self.ys.push(y);
    }

    pub fn smoothed(&self, window: usize) -> Series {
        Series {
            name: self.name.clone(),
            xs: self.xs.clone(),
            ys: stats::smooth(&self.ys, window),
        }
    }

    pub fn last(&self) -> Option<f64> {
        self.ys.last().copied()
    }

    /// Mean of the final `k` values — the "convergent value" of a curve.
    pub fn tail_mean(&self, k: usize) -> f64 {
        if self.ys.is_empty() {
            return 0.0;
        }
        let lo = self.ys.len().saturating_sub(k);
        stats::mean(&self.ys[lo..])
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("x", self.xs.iter().map(|&v| Json::Num(v)).collect::<Vec<_>>())
            .set("y", self.ys.iter().map(|&v| Json::Num(v)).collect::<Vec<_>>())
    }
}

/// A figure-shaped collection of series plus free-form scalar facts.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub title: String,
    pub series: Vec<Series>,
    pub facts: Vec<(String, f64)>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Report {
        Report {
            title: title.into(),
            ..Default::default()
        }
    }

    pub fn add_series(&mut self, s: Series) {
        self.series.push(s);
    }

    pub fn fact(&mut self, name: impl Into<String>, value: f64) {
        self.facts.push((name.into(), value));
    }

    pub fn series_named(&self, name: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.name == name)
    }

    /// Write `results/<slug>.json` + `results/<slug>.csv`.
    pub fn write(&self, dir: impl AsRef<Path>, slug: &str) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let mut j = Json::obj().set("title", self.title.as_str());
        j = j.set(
            "series",
            Json::Arr(self.series.iter().map(|s| s.to_json()).collect()),
        );
        let mut facts = Json::obj();
        for (k, v) in &self.facts {
            facts = facts.set(k, *v);
        }
        j = j.set("facts", facts);
        j.write_file(dir.join(format!("{slug}.json")))?;
        std::fs::write(dir.join(format!("{slug}.csv")), self.to_csv())?;
        Ok(())
    }

    /// Long-format CSV: series,x,y
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,x,y\n");
        for s in &self.series {
            for (x, y) in s.xs.iter().zip(&s.ys) {
                out.push_str(&format!("{},{x},{y}\n", s.name));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_tail_mean() {
        let mut s = Series::new("r");
        for i in 0..10 {
            s.push(i as f64, if i < 8 { 0.0 } else { 4.0 });
        }
        assert_eq!(s.tail_mean(2), 4.0);
        assert_eq!(s.last(), Some(4.0));
    }

    #[test]
    fn report_roundtrip() {
        let mut r = Report::new("Fig. X");
        let mut s = Series::new("mahppo");
        s.push(0.0, -1.0);
        s.push(1.0, -0.5);
        r.add_series(s);
        r.fact("headline", 0.56);
        let dir = std::env::temp_dir().join("macci_report_test");
        r.write(&dir, "figx").unwrap();
        let j = Json::parse_file(dir.join("figx.json")).unwrap();
        assert_eq!(j.str_of("title").unwrap(), "Fig. X");
        let csv = std::fs::read_to_string(dir.join("figx.csv")).unwrap();
        assert!(csv.contains("mahppo,0,-1"));
    }
}
