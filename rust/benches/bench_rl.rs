//! RL plumbing benches: sampling, GAE, buffer ops (pure Rust, no PJRT).

use macci::rl::buffer::{TrajectoryBuffer, Transition};
use macci::rl::gae;
use macci::rl::sampling;
use macci::runtime::nets::ActorOutput;
use macci::util::bench::{black_box, Bench};
use macci::util::rng::Rng;

fn main() {
    let mut b = Bench::new("rl");
    let mut rng = Rng::new(1);

    let out = ActorOutput {
        probs_b: vec![0.3, 0.2, 0.1, 0.15, 0.15, 0.1],
        probs_c: vec![0.6, 0.4],
        mu: 0.2,
        log_std: -0.5,
    };
    b.run("sample_hybrid", || {
        black_box(sampling::sample_hybrid(black_box(&out), &mut rng));
    });

    let n = 1024;
    let rewards: Vec<f64> = (0..n).map(|i| -1.0 - (i % 13) as f64 * 0.1).collect();
    let values: Vec<f32> = (0..n).map(|i| -((i % 7) as f32)).collect();
    let mut dones = vec![false; n];
    for i in (63..n).step_by(64) {
        dones[i] = true;
    }
    b.run("gae_1024", || {
        black_box(gae::gae_advantages(
            black_box(&rewards),
            black_box(&values),
            black_box(&dones),
            0.95,
            0.95,
            0.0,
        ));
    });
    b.run("returns_1024", || {
        black_box(gae::discounted_returns(
            black_box(&rewards),
            black_box(&dones),
            0.95,
            0.0,
        ));
    });

    // buffer fill + minibatch gather
    let make_t = |i: usize| Transition {
        state: vec![0.1; 20],
        a_b: vec![(i % 6) as i32; 5],
        a_c: vec![(i % 2) as i32; 5],
        a_p: vec![0.1; 5],
        log_prob: vec![-1.5; 5],
        reward: -1.0,
        value: -0.5,
        done: i % 64 == 63,
    };
    let mut buf = TrajectoryBuffer::new(1024, 5);
    for i in 0..1024 {
        buf.push(make_t(i));
    }
    buf.finish(0.95, 0.95, 0.0, true);
    let mut rng2 = Rng::new(2);
    b.run("minibatch_256_of_1024", || {
        black_box(buf.sample_minibatch(256, &mut rng2));
    });

    b.run("buffer_push_1024", || {
        let mut buf = TrajectoryBuffer::new(1024, 5);
        for i in 0..1024 {
            buf.push(make_t(i));
        }
        black_box(buf.len());
    });

    b.report();
}
