//! Serving-throughput bench: the threaded edge server with the inline
//! serial path (workers = 0) vs the pooled + batched offload executor, at
//! 1 / 4 / 16 concurrent closed-loop UEs. Emits BENCH_serving.json.
//!
//! Runs fully offline on the synthetic offload compute (fixed per-item
//! cost, batches amortized per the `_full_b8`-style model documented in
//! `coordinator::executor`); real artifact timings live in
//! BENCH_runtime.json. The figure of merit is end-to-end requests/s
//! through the server loop, so routing, batching, queueing and channel
//! overheads are all on the clock.

use std::sync::Arc;
use std::time::{Duration, Instant};

use macci::coordinator::decision::{DecisionMaker, StaticDecision};
use macci::coordinator::executor::{ExecutorConfig, OffloadCompute, SyntheticCompute};
use macci::coordinator::protocol::{Downlink, OffloadRequest, UeStateReport, Uplink};
use macci::coordinator::server::{EdgeServer, ServerConfig};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::env::HybridAction;
use macci::util::json::Json;

const ITEM_COST: Duration = Duration::from_micros(500);

/// One serving run; returns end-to-end throughput in requests/s plus the
/// offload-cache counters. `cache_entries` sizes the content-addressed
/// result cache (0 = off); `distinct` > 0 draws each task's payload from
/// that many distinct contents (shared across UEs), so the steady-state
/// hit ratio approaches `1 - distinct / total_tasks`.
fn run_one(
    n_ues: usize,
    workers: usize,
    tasks_per_ue: u64,
    cache_entries: usize,
    distinct: u64,
) -> (f64, macci::coordinator::offload_cache::CacheStats) {
    let compute = Arc::new(SyntheticCompute::new(ITEM_COST));
    let elems = compute.image_elems;
    let pool = StatePool::new(
        n_ues,
        StateNorm {
            lambda_tasks: tasks_per_ue as f64,
            frame_s: 0.5,
            max_bits: 1e6,
            d_max: 100.0,
        },
    );
    let decisions = DecisionMaker::new(Box::new(StaticDecision::new(vec![
        HybridAction::new(0, 0, 0.0, 1.0);
        n_ues
    ])));
    let mut cfg = ServerConfig::new(n_ues, Duration::from_millis(10), usize::MAX);
    cfg.offload_cache = cache_entries;
    cfg.exec = ExecutorConfig {
        workers,
        max_batch: 8,
        // short: closed-loop UEs rarely fill a batch, so don't idle on it
        max_wait: Duration::from_micros(100),
        ..ExecutorConfig::default()
    };
    let compute = Some(compute as Arc<dyn OffloadCompute>);
    let (server, downlinks) = EdgeServer::spawn(cfg, pool, decisions, compute).unwrap();

    let t0 = Instant::now();
    let handles: Vec<_> = downlinks
        .into_iter()
        .enumerate()
        .map(|(ue, rx)| {
            let uplink = server.uplink.clone();
            std::thread::spawn(move || {
                uplink
                    .send(Uplink::Report(UeStateReport {
                        ue_id: ue,
                        tasks_left: tasks_per_ue,
                        compute_left_s: 0.0,
                        offload_left_bits: 0.0,
                        distance_m: 40.0,
                    }))
                    .unwrap();
                for task in 0..tasks_per_ue {
                    // distinct = 0 keeps the original constant payload;
                    // otherwise rotate through `distinct` contents so the
                    // offload cache sees a controlled duplicate ratio
                    let fill = if distinct == 0 {
                        1u8
                    } else {
                        (task % distinct.min(250)) as u8 + 1
                    };
                    uplink
                        .send(Uplink::Offload(OffloadRequest {
                            ue_id: ue,
                            task_id: task,
                            b: 0,
                            payload: vec![fill; 4 * elems],
                            calibration: None,
                        }))
                        .unwrap();
                    loop {
                        match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
                            Downlink::Result(_) => break,
                            Downlink::Decision(_) => {}
                            Downlink::Error { error, .. } => panic!("offload failed: {error}"),
                            Downlink::Shutdown => panic!("server shut down early"),
                        }
                    }
                }
                uplink.send(Uplink::Goodbye { ue_id: ue }).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let stats = server.join();
    let wall = t0.elapsed().as_secs_f64();
    let total = n_ues as u64 * tasks_per_ue;
    assert_eq!(stats.offloads_served as u64, total, "bench run lost tasks");
    (total as f64 / wall, stats.cache)
}

fn main() {
    let tasks: u64 = macci::util::config::bench_serving_tasks(64);
    let pooled_workers = 4;

    println!(
        "serving bench: synthetic compute {:.0} µs/item, {} tasks/UE, pooled = {} workers + batch",
        ITEM_COST.as_secs_f64() * 1e6,
        tasks,
        pooled_workers
    );
    let mut json = Json::obj();
    for &n_ues in &[1usize, 4, 16] {
        let (inline, _) = run_one(n_ues, 0, tasks, 0, 0);
        let (pooled, _) = run_one(n_ues, pooled_workers, tasks, 0, 0);
        println!(
            "  {n_ues:>2} UEs: inline-serial {inline:>8.1} req/s | \
             pooled-batched {pooled:>8.1} req/s | speedup {:.2}x",
            pooled / inline
        );
        json = json
            .set(
                &format!("serving/inline_ues{n_ues}"),
                Json::obj().set("req_per_s", inline),
            )
            .set(
                &format!("serving/pooled_ues{n_ues}"),
                Json::obj().set("req_per_s", pooled),
            )
            .set(&format!("serving/speedup_ues{n_ues}"), pooled / inline);
    }

    // offload-cache sweep: the same closed-loop run, 4 UEs × pooled
    // executor, with the payload pool shrunk so the duplicate ratio (and
    // thus the hit ratio) climbs — the uncached row is the baseline
    let sweep_ues = 4usize;
    let (baseline, _) = run_one(sweep_ues, pooled_workers, tasks, 0, 8);
    json = json.set(
        "serving/cache_off_distinct8",
        Json::obj().set("req_per_s", baseline),
    );
    for &distinct in &[64u64, 8, 1] {
        let (rate, cache) = run_one(sweep_ues, pooled_workers, tasks, 256, distinct);
        let lookups = cache.hits + cache.misses;
        let hit_ratio = cache.hits as f64 / (lookups.max(1)) as f64;
        println!(
            "  cache sweep ({sweep_ues} UEs, {distinct:>2} distinct payloads): \
             {rate:>8.1} req/s | hit ratio {:.2} | {} hits / {} misses",
            hit_ratio, cache.hits, cache.misses
        );
        json = json.set(
            &format!("serving/cache_distinct{distinct}"),
            Json::obj()
                .set("req_per_s", rate)
                .set("hit_ratio", hit_ratio)
                .set("hits", cache.hits as usize)
                .set("misses", cache.misses as usize)
                .set("bytes_saved", cache.bytes_saved as usize),
        );
    }
    json.write_file("BENCH_serving.json").unwrap();
    println!("wrote BENCH_serving.json");
}
