//! L3 hot-path benches: environment stepping and the channel model.
//! (Paper-table relevance: every training frame of Figs. 8-13 pays these.)

use macci::env::channel::{ChannelModel, Transmitter};
use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::ScenarioConfig;
use macci::env::{Action, HybridAction};
use macci::profiles::DeviceProfile;
use macci::util::bench::{black_box, Bench};
use macci::util::rng::Rng;

fn main() {
    let mut b = Bench::new("env");

    // channel model Eq. 5 at several transmitter counts
    for n in [2usize, 5, 10] {
        let model = ChannelModel {
            bandwidth_hz: 1e6,
            noise_w: 1e-9,
            n_channels: 2,
        };
        let mut rng = Rng::new(1);
        let txs: Vec<Transmitter> = (0..n)
            .map(|i| Transmitter {
                ue: i,
                channel: i % 2,
                power_w: rng.uniform(0.1, 1.0),
                gain: rng.uniform(1.0, 100.0).powf(-3.0),
            })
            .collect();
        b.run(&format!("uplink_rates_n{n}"), || {
            black_box(model.rates(black_box(&txs)));
        });
    }

    // full env.step under three policies
    for (name, bsel) in [("local", 5usize), ("split2", 2), ("raw", 0)] {
        let cfg = ScenarioConfig {
            n_ues: 5,
            lambda_tasks: 1e9, // never exhausts mid-bench
            ..Default::default()
        };
        let mut env = MultiAgentEnv::new(DeviceProfile::synthetic(), cfg, 3).unwrap();
        let actions: Action = (0..5)
            .map(|i| HybridAction::new(bsel, i % 2, 1.0, 1.0))
            .collect();
        b.run(&format!("env_step_{name}"), || {
            black_box(env.step(black_box(&actions)));
        });
    }

    // state encoding alone
    let cfg = ScenarioConfig {
        n_ues: 10,
        ..Default::default()
    };
    let env = MultiAgentEnv::new(DeviceProfile::synthetic(), cfg, 4).unwrap();
    b.run("state_encode_n10", || {
        black_box(env.state());
    });

    b.report();
}
