//! Runtime benches: the artifact executions on every hot path, on whatever
//! backend the store resolves (native interpreter by default, so this runs
//! fully offline). Results also land in BENCH_runtime.json as the perf
//! baseline for the scaling roadmap.
//!
//! Paper-table relevance: actor_fwd dominates the per-frame decision cost
//! (Figs. 8-13 training wall time); *_update dominates the PPO rounds.

use macci::runtime::artifacts::ArtifactStore;
use macci::runtime::native::gemm::{dense_packed, PackedW};
use macci::runtime::native::kernels::{dense_with, Act};
use macci::runtime::native::quant8::QuantDense;
use macci::runtime::native::simd::{self, Isa};
use macci::runtime::nets::{ActorNet, CriticNet};
use macci::util::bench::{black_box, Bench};
use macci::util::rng::Rng;

/// Per-kernel dense timings: f32 scalar reference vs the dispatched
/// SIMD/blocked GEMM vs the int8 path, at a hidden-layer-sized 256→128
/// matmul (Act::Linear so the activation cost doesn't mask the GEMM).
fn kernel_benches(b: &mut Bench, rng: &mut Rng) {
    let (in_dim, out_dim) = (256usize, 128usize);
    let w: Vec<f32> = (0..in_dim * out_dim).map(|_| rng.f32() - 0.5).collect();
    let bias: Vec<f32> = (0..out_dim).map(|_| rng.f32() - 0.5).collect();
    // packing happens once per params version in the serving path — keep
    // it out of the timed region
    let pw = PackedW::pack(&w, &bias, in_dim, out_dim);
    let qd = QuantDense::pack(&w, &bias, in_dim, out_dim);
    let isa = simd::active();
    println!("kernel isa: {isa:?}");
    let mut speedup = Vec::new();
    for rows in [1usize, 8, 32] {
        let x: Vec<f32> = (0..rows * in_dim).map(|_| rng.f32() - 0.5).collect();
        let flops = (2 * rows * in_dim * out_dim) as f64;
        b.run(&format!("dense_b{rows}_f32_scalar"), || {
            black_box(dense_with(
                Isa::Scalar,
                black_box(&x),
                rows,
                in_dim,
                &w,
                &bias,
                out_dim,
                Act::Linear,
            ));
        });
        let scalar_ns = b.results().last().unwrap().mean_ns;
        b.gauge(format!("dense_b{rows}_f32_scalar_gflops"), flops / scalar_ns);
        b.run(&format!("dense_b{rows}_f32_simd"), || {
            black_box(dense_packed(isa, black_box(&x), rows, &pw, Act::Linear));
        });
        let simd_ns = b.results().last().unwrap().mean_ns;
        b.gauge(format!("dense_b{rows}_f32_simd_gflops"), flops / simd_ns);
        b.run(&format!("dense_b{rows}_int8"), || {
            black_box(qd.forward(isa, black_box(&x), rows, Act::Linear));
        });
        let q8_ns = b.results().last().unwrap().mean_ns;
        b.gauge(format!("dense_b{rows}_int8_gflops"), flops / q8_ns);
        speedup.push((rows, scalar_ns / simd_ns, scalar_ns / q8_ns));
    }
    for (rows, s_simd, s_q8) in speedup {
        b.gauge(format!("dense_b{rows}_simd_speedup"), s_simd);
        b.gauge(format!("dense_b{rows}_int8_speedup"), s_q8);
    }
}

fn main() {
    let store = match ArtifactStore::open("artifacts") {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping runtime benches: {e:#}");
            return;
        }
    };
    let mut b = Bench::new("runtime");
    println!("backend: {}", store.backend_name());
    let mut rng = Rng::new(1);

    kernel_benches(&mut b, &mut rng);

    let mut actor = ActorNet::new(&store, 5, 1).unwrap();
    let mut critic = CriticNet::new(&store, 5, 2).unwrap();
    let state: Vec<f32> = (0..20).map(|_| rng.f32()).collect();

    b.run("actor_fwd_b1_n5", || {
        black_box(actor.forward(black_box(&state)).unwrap());
    });
    b.run("actor_fwd_b1_n5_uncached", || {
        // §Perf baseline: rebuilds the 64k-float params literal per call
        black_box(actor.forward_uncached(black_box(&state)).unwrap());
    });
    b.run("critic_fwd_b1_n5", || {
        black_box(critic.value(black_box(&state)).unwrap());
    });

    // a full 5-actor decision (what one env frame costs in net evals)
    let mut actors: Vec<ActorNet> = (0..5).map(|i| ActorNet::new(&store, 5, i).unwrap()).collect();
    b.run("joint_decision_n5", || {
        for a in actors.iter_mut() {
            black_box(a.forward(black_box(&state)).unwrap());
        }
        black_box(critic.value(black_box(&state)).unwrap());
    });

    // PPO minibatch updates at B = 256
    let bsz = 256;
    let states: Vec<f32> = (0..bsz * 20).map(|_| rng.f32()).collect();
    let a_b = vec![2i32; bsz];
    let a_c = vec![1i32; bsz];
    let a_p = vec![0.1f32; bsz];
    let olp = vec![-2.0f32; bsz];
    let adv = vec![0.5f32; bsz];
    let returns = vec![-1.0f32; bsz];
    let mut actor_mut = ActorNet::new(&store, 5, 3).unwrap();
    let mut critic_mut = CriticNet::new(&store, 5, 4).unwrap();
    b.run("actor_update_b256_n5", || {
        black_box(
            actor_mut
                .update(1e-4, &states, &a_b, &a_c, &a_p, &olp, &adv)
                .unwrap(),
        );
    });
    b.run("critic_update_b256_n5", || {
        black_box(critic_mut.update(1e-4, &states, &returns).unwrap());
    });

    b.report();
    // perf-trajectory baseline (diffed across PRs, see ci.sh)
    b.merge_into("BENCH_runtime.json");
}
