//! Runtime benches: the artifact executions on every hot path, on whatever
//! backend the store resolves (native interpreter by default, so this runs
//! fully offline). Results also land in BENCH_runtime.json as the perf
//! baseline for the scaling roadmap.
//!
//! Paper-table relevance: actor_fwd dominates the per-frame decision cost
//! (Figs. 8-13 training wall time); *_update dominates the PPO rounds.

use macci::runtime::artifacts::ArtifactStore;
use macci::runtime::nets::{ActorNet, CriticNet};
use macci::util::bench::{black_box, Bench};
use macci::util::rng::Rng;

fn main() {
    let store = match ArtifactStore::open("artifacts") {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping runtime benches: {e:#}");
            return;
        }
    };
    let mut b = Bench::new("runtime");
    println!("backend: {}", store.backend_name());
    let mut rng = Rng::new(1);

    let mut actor = ActorNet::new(&store, 5, 1).unwrap();
    let mut critic = CriticNet::new(&store, 5, 2).unwrap();
    let state: Vec<f32> = (0..20).map(|_| rng.f32()).collect();

    b.run("actor_fwd_b1_n5", || {
        black_box(actor.forward(black_box(&state)).unwrap());
    });
    b.run("actor_fwd_b1_n5_uncached", || {
        // §Perf baseline: rebuilds the 64k-float params literal per call
        black_box(actor.forward_uncached(black_box(&state)).unwrap());
    });
    b.run("critic_fwd_b1_n5", || {
        black_box(critic.value(black_box(&state)).unwrap());
    });

    // a full 5-actor decision (what one env frame costs in net evals)
    let mut actors: Vec<ActorNet> = (0..5).map(|i| ActorNet::new(&store, 5, i).unwrap()).collect();
    b.run("joint_decision_n5", || {
        for a in actors.iter_mut() {
            black_box(a.forward(black_box(&state)).unwrap());
        }
        black_box(critic.value(black_box(&state)).unwrap());
    });

    // PPO minibatch updates at B = 256
    let bsz = 256;
    let states: Vec<f32> = (0..bsz * 20).map(|_| rng.f32()).collect();
    let a_b = vec![2i32; bsz];
    let a_c = vec![1i32; bsz];
    let a_p = vec![0.1f32; bsz];
    let olp = vec![-2.0f32; bsz];
    let adv = vec![0.5f32; bsz];
    let returns = vec![-1.0f32; bsz];
    let mut actor_mut = ActorNet::new(&store, 5, 3).unwrap();
    let mut critic_mut = CriticNet::new(&store, 5, 4).unwrap();
    b.run("actor_update_b256_n5", || {
        black_box(
            actor_mut
                .update(1e-4, &states, &a_b, &a_c, &a_p, &olp, &adv)
                .unwrap(),
        );
    });
    b.run("critic_update_b256_n5", || {
        black_box(critic_mut.update(1e-4, &states, &returns).unwrap());
    });

    b.report();
    // perf-trajectory baseline (diffed across PRs, see ci.sh)
    b.merge_into("BENCH_runtime.json");
}
