//! Compression benches: quantizer, bit-packing, Huffman, JALAD pipeline.
//! (Paper-table relevance: Fig. 4 rates + the t_c overheads of Fig. 7.)

use macci::compress::huffman::HuffmanCoder;
use macci::compress::jalad::JaladCompressor;
use macci::compress::quant::{calibrate, Quantizer};
use macci::util::bench::{black_box, Bench};
use macci::util::rng::Rng;

fn feature(n: usize, sparsity: f64, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            if rng.f64() < sparsity {
                0.0
            } else {
                rng.normal().abs() as f32
            }
        })
        .collect()
}

fn main() {
    let mut b = Bench::new("compress");
    // p2-sized resnet18 feature at paper scale: 128 x 28 x 28
    let feat = feature(128 * 28 * 28, 0.6, 1);
    let (lo, hi) = calibrate(&feat);
    let q8 = Quantizer::new(8).unwrap();

    b.run("calibrate_100k", || {
        black_box(calibrate(black_box(&feat)));
    });
    b.run("quantize8_100k", || {
        black_box(q8.quantize(black_box(&feat), lo, hi));
    });
    let codes = q8.quantize(&feat, lo, hi);
    b.run("dequantize8_100k", || {
        black_box(q8.dequantize(black_box(&codes), lo, hi));
    });
    b.run("pack8_100k", || {
        black_box(q8.pack(black_box(&codes)));
    });
    let bytes: Vec<u8> = codes.iter().map(|&c| c as u8).collect();
    let coder = HuffmanCoder::new();
    b.run("huffman_encode_100k", || {
        black_box(coder.encode(black_box(&bytes)));
    });
    let block = coder.encode(&bytes);
    b.run("huffman_decode_100k", || {
        black_box(coder.decode(black_box(&block)).unwrap());
    });
    let jalad = JaladCompressor::new();
    b.run("jalad_pipeline_100k", || {
        black_box(jalad.compress(black_box(&feat)));
    });
    b.report();
}
