//! Wire-codec throughput: encode + decode for the two offload shapes the
//! serving path actually ships — a raw-input frame (f32 image bytes) and
//! an AE-coded feature frame (packed codes + calibration) — plus the
//! small control frames (report / decision / result). Emits
//! BENCH_wire.json with per-op times and effective MB/s, next to
//! BENCH_serving.json in ci.sh.

use macci::coordinator::protocol::{
    Downlink, FrameDecision, InferenceResult, OffloadRequest, UeStateReport, Uplink,
};
use macci::coordinator::wire::{decode_frame, encode_frame, Frame};
use macci::env::HybridAction;
use macci::util::bench::{black_box, Bench};
use macci::util::json::Json;

/// Raw offload: a 3×32×32 f32 image (the demo backbone's input), 12 KiB.
fn raw_offload() -> Frame {
    let elems = 3 * 32 * 32;
    let payload: Vec<u8> = (0..elems)
        .flat_map(|i| ((i % 251) as f32 / 251.0).to_le_bytes())
        .collect();
    Frame::Up(Uplink::Offload(OffloadRequest {
        ue_id: 1,
        task_id: 42,
        b: 0,
        payload,
        calibration: None,
    }))
}

/// AE-coded offload: 8 compressed channels at 16×16, 8-bit codes — the
/// paper's compressed-feature shape, 2 KiB on the wire.
fn ae_offload() -> Frame {
    let payload: Vec<u8> = (0..8 * 16 * 16).map(|i| (i % 256) as u8).collect();
    Frame::Up(Uplink::Offload(OffloadRequest {
        ue_id: 1,
        task_id: 43,
        b: 2,
        payload,
        calibration: Some((-1.25, 3.5)),
    }))
}

fn report_frame() -> Frame {
    Frame::Up(Uplink::Report(UeStateReport {
        ue_id: 3,
        tasks_left: 17,
        compute_left_s: 0.02,
        offload_left_bits: 1e5,
        distance_m: 50.0,
    }))
}

fn decision_frame(n_ues: usize) -> Frame {
    Frame::Down(Downlink::Decision(FrameDecision {
        frame: 7,
        actions: vec![HybridAction::new(2, 1, 0.3, 1.0); n_ues].into(),
    }))
}

fn result_frame() -> Frame {
    Frame::Down(Downlink::Result(InferenceResult {
        ue_id: 3,
        task_id: 42,
        logits: (0..101).map(|i| i as f32 * 0.01).collect(),
        argmax: 100,
        edge_latency_s: 0.004,
    }))
}

fn main() {
    let cases: Vec<(&str, Frame)> = vec![
        ("raw_offload", raw_offload()),
        ("ae_offload", ae_offload()),
        ("report", report_frame()),
        ("decision_ues16", decision_frame(16)),
        ("result", result_frame()),
    ];

    let mut b = Bench::new("wire");
    let mut sizes = Vec::new();
    for (name, frame) in &cases {
        let encoded = encode_frame(frame);
        sizes.push((name.to_string(), encoded.len()));
        println!("{name}: {} bytes on the wire", encoded.len());
        b.run(&format!("encode_{name}"), || {
            black_box(encode_frame(black_box(frame)));
        });
        b.run(&format!("decode_{name}"), || {
            black_box(decode_frame(black_box(&encoded)).expect("valid frame"));
        });
    }
    b.report();

    // per-case effective throughput (frame bytes / mean time)
    let mut json = Json::obj();
    for r in b.results() {
        let case = r.name.trim_start_matches("encode_").trim_start_matches("decode_");
        let bytes = sizes
            .iter()
            .find(|(n, _)| n.as_str() == case)
            .map(|&(_, s)| s)
            .unwrap_or(0);
        let mb_per_s = bytes as f64 / (r.mean_ns / 1e9) / 1e6;
        json = json.set(
            &format!("wire/{}", r.name),
            Json::obj()
                .set("mean_ns", r.mean_ns)
                .set("p99_ns", r.p99_ns)
                .set("frame_bytes", bytes as f64)
                .set("mb_per_s", mb_per_s),
        );
        println!("{:>24}: {:8.1} MB/s", r.name, mb_per_s);
    }
    json.write_file("BENCH_wire.json").unwrap();
    println!("wrote BENCH_wire.json");
}
