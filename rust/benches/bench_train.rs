//! Training throughput, both halves of the MAHPPO loop. Emits
//! BENCH_train.json.
//!
//! Rollout: the vectorized engine vs the serial collection loop at
//! E = 1 / 4 / 8 env lanes. Runs fully offline on the native backend with
//! the built-in RL demo manifest and the synthetic device profile, so the
//! numbers isolate the engine itself: batched actor/critic forwards,
//! per-lane sampling, env stepping on the worker-thread pool. E = 1 is
//! bit-for-bit the serial MAHPPO collection loop and serves as the
//! baseline.
//!
//! Update: the sharded PPO update engine at W = 1 / 2 / 4 workers —
//! updates/s across one full round (N actor steps + one critic step at
//! B = 256) plus the per-round wall time, which is exactly the stall an
//! inline learner pays per update round. W = 1 runs the shards on the
//! caller thread and is the serial baseline; every W produces the same
//! parameter bits.
//!
//! Bounded by MACCI_BENCH_MS per configuration like the other benches.

use std::time::{Duration, Instant};

use macci::env::scenario::ScenarioConfig;
use macci::profiles::DeviceProfile;
use macci::rl::mahppo::TrainConfig;
use macci::rl::rollout::RolloutEngine;
use macci::runtime::artifacts::ArtifactStore;
use macci::runtime::nets::{ActorNet, CriticNet};
use macci::util::json::Json;
use macci::util::rng::Rng;

const N_UES: usize = 5;
const BUFFER: usize = 512;

/// Collect rollout buffers for ~`target` wall time; returns frames/s.
fn run_one(store: &ArtifactStore, n_envs: usize, target: Duration) -> f64 {
    let scenario = ScenarioConfig {
        n_ues: N_UES,
        lambda_tasks: 40.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: BUFFER,
        minibatch: 128,
        n_envs,
        seed: 17,
        ..Default::default()
    };
    let mut actors: Vec<ActorNet> = (0..N_UES)
        .map(|i| ActorNet::new(store, N_UES, cfg.actor_seed(i)).unwrap())
        .collect();
    let mut critic = CriticNet::new(store, N_UES, cfg.critic_seed()).unwrap();
    let mut engine = RolloutEngine::new(&DeviceProfile::synthetic(), &scenario, &cfg).unwrap();
    let mut rng = Rng::new(cfg.sampler_seed());
    let mut buf = engine.make_buffer(cfg.buffer_size);
    engine.reset().unwrap();

    // warmup: one buffer
    engine.collect(&mut actors, &mut critic, &mut buf, &mut rng).unwrap();
    buf.clear();

    let mut frames = 0usize;
    let t0 = Instant::now();
    while t0.elapsed() < target {
        let stats = engine.collect(&mut actors, &mut critic, &mut buf, &mut rng).unwrap();
        frames += stats.frames;
        buf.clear();
    }
    frames as f64 / t0.elapsed().as_secs_f64()
}

/// One PPO update round = one Adam step per actor plus one critic step,
/// all at B = 256, repeated for ~`target` wall time on `workers` update
/// workers. Returns (updates/s, mean round wall time in ms) — the latter
/// is the stall an inline learner pays per round.
fn run_update(store: &ArtifactStore, workers: usize, target: Duration) -> (f64, f64) {
    let b = 256usize;
    let d = 4 * N_UES;
    let mut rng = Rng::new(23);
    let states: Vec<f32> = (0..b * d).map(|_| rng.f32()).collect();
    let a_b: Vec<i32> = (0..b).map(|i| (i % 6) as i32).collect();
    let a_c: Vec<i32> = (0..b).map(|i| (i % 2) as i32).collect();
    let a_p: Vec<f32> = (0..b).map(|_| 0.2 + 0.6 * rng.f32()).collect();
    let old_logp: Vec<f32> = (0..b).map(|_| -3.0 * rng.f32()).collect();
    let adv: Vec<f32> = (0..b).map(|_| 2.0 * rng.f32() - 1.0).collect();
    let returns: Vec<f32> = (0..b).map(|_| -2.0 * rng.f32()).collect();

    let mut actors: Vec<ActorNet> = (0..N_UES)
        .map(|i| {
            let mut a = ActorNet::new(store, N_UES, 100 + i as u64).unwrap();
            a.set_update_threads(workers);
            a
        })
        .collect();
    let mut critic = CriticNet::new(store, N_UES, 99).unwrap();
    critic.set_update_threads(workers);

    let round = |actors: &mut Vec<ActorNet>, critic: &mut CriticNet| {
        critic.update(1e-3, &states, &returns).unwrap();
        for a in actors.iter_mut() {
            a.update(1e-3, &states, &a_b, &a_c, &a_p, &old_logp, &adv).unwrap();
        }
    };
    // warmup: workspace arenas reach steady-state capacity
    round(&mut actors, &mut critic);

    let (mut updates, mut rounds) = (0usize, 0usize);
    let t0 = Instant::now();
    while t0.elapsed() < target {
        round(&mut actors, &mut critic);
        updates += N_UES + 1;
        rounds += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    (updates as f64 / dt, dt * 1e3 / rounds as f64)
}

fn main() {
    let target = Duration::from_millis(macci::util::config::bench_ms(700));
    let store = ArtifactStore::native_demo();
    println!(
        "train-rollout bench: N = {N_UES} UEs, |M| = {BUFFER}, native backend, {} ms/config",
        target.as_millis()
    );

    let mut json = Json::obj();
    let mut serial = 0.0f64;
    for &e in &[1usize, 4, 8] {
        let fps = run_one(&store, e, target);
        if e == 1 {
            serial = fps;
        }
        let label = if e == 1 { "serial" } else { "vectorized" };
        println!(
            "  E = {e}: {fps:>9.0} frames/s ({label}){}",
            if e == 1 {
                String::new()
            } else {
                format!("  | speedup vs serial {:.2}x", fps / serial)
            }
        );
        json = json.set(
            &format!("train/rollout_e{e}"),
            Json::obj().set("frames_per_s", fps),
        );
        if e > 1 {
            json = json.set(&format!("train/speedup_e{e}"), fps / serial);
        }
    }

    println!("update engine: B = 256, {} nets/round", N_UES + 1);
    let mut serial_ups = 0.0f64;
    for &w in &[1usize, 2, 4] {
        let (ups, round_ms) = run_update(&store, w, target);
        if w == 1 {
            serial_ups = ups;
        }
        println!(
            "  W = {w}: {ups:>7.1} updates/s, {round_ms:>7.2} ms/round (learner stall){}",
            if w == 1 {
                String::new()
            } else {
                format!("  | speedup vs serial {:.2}x", ups / serial_ups)
            }
        );
        json = json.set(
            &format!("train/update_w{w}"),
            Json::obj()
                .set("updates_per_s", ups)
                .set("stall_ms", round_ms),
        );
        if w > 1 {
            json = json.set(&format!("train/update_speedup_w{w}"), ups / serial_ups);
        }
    }
    json.write_file("BENCH_train.json").unwrap();
    println!("wrote BENCH_train.json");
}
