//! Training-rollout throughput: the vectorized rollout engine vs the
//! serial collection loop, at E = 1 / 4 / 8 env lanes. Emits
//! BENCH_train.json.
//!
//! Runs fully offline on the native backend with the built-in RL demo
//! manifest and the synthetic device profile, so the numbers isolate the
//! engine itself: batched actor/critic forwards, per-lane sampling, env
//! stepping on the worker-thread pool. E = 1 is bit-for-bit the serial
//! MAHPPO collection loop and serves as the baseline. PPO update cost is
//! identical in both modes and excluded (rollout was the serial bottleneck
//! this engine removes).
//!
//! Bounded by MACCI_BENCH_MS per configuration like the other benches.

use std::time::{Duration, Instant};

use macci::env::scenario::ScenarioConfig;
use macci::profiles::DeviceProfile;
use macci::rl::mahppo::TrainConfig;
use macci::rl::rollout::RolloutEngine;
use macci::runtime::artifacts::ArtifactStore;
use macci::runtime::nets::{ActorNet, CriticNet};
use macci::util::json::Json;
use macci::util::rng::Rng;

const N_UES: usize = 5;
const BUFFER: usize = 512;

/// Collect rollout buffers for ~`target` wall time; returns frames/s.
fn run_one(store: &ArtifactStore, n_envs: usize, target: Duration) -> f64 {
    let scenario = ScenarioConfig {
        n_ues: N_UES,
        lambda_tasks: 40.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: BUFFER,
        minibatch: 128,
        n_envs,
        seed: 17,
        ..Default::default()
    };
    let mut actors: Vec<ActorNet> = (0..N_UES)
        .map(|i| ActorNet::new(store, N_UES, cfg.actor_seed(i)).unwrap())
        .collect();
    let mut critic = CriticNet::new(store, N_UES, cfg.critic_seed()).unwrap();
    let mut engine = RolloutEngine::new(&DeviceProfile::synthetic(), &scenario, &cfg).unwrap();
    let mut rng = Rng::new(cfg.sampler_seed());
    let mut buf = engine.make_buffer(cfg.buffer_size);
    engine.reset().unwrap();

    // warmup: one buffer
    engine.collect(&mut actors, &mut critic, &mut buf, &mut rng).unwrap();
    buf.clear();

    let mut frames = 0usize;
    let t0 = Instant::now();
    while t0.elapsed() < target {
        let stats = engine.collect(&mut actors, &mut critic, &mut buf, &mut rng).unwrap();
        frames += stats.frames;
        buf.clear();
    }
    frames as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let target = Duration::from_millis(macci::util::config::bench_ms(700));
    let store = ArtifactStore::native_demo();
    println!(
        "train-rollout bench: N = {N_UES} UEs, |M| = {BUFFER}, native backend, {} ms/config",
        target.as_millis()
    );

    let mut json = Json::obj();
    let mut serial = 0.0f64;
    for &e in &[1usize, 4, 8] {
        let fps = run_one(&store, e, target);
        if e == 1 {
            serial = fps;
        }
        let label = if e == 1 { "serial" } else { "vectorized" };
        println!(
            "  E = {e}: {fps:>9.0} frames/s ({label}){}",
            if e == 1 {
                String::new()
            } else {
                format!("  | speedup vs serial {:.2}x", fps / serial)
            }
        );
        json = json.set(
            &format!("train/rollout_e{e}"),
            Json::obj().set("frames_per_s", fps),
        );
        if e > 1 {
            json = json.set(&format!("train/speedup_e{e}"), fps / serial);
        }
    }
    json.write_file("BENCH_train.json").unwrap();
    println!("wrote BENCH_train.json");
}
