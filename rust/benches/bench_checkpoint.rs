//! Policy-lifecycle baseline: checkpoint encode/decode throughput,
//! snapshot size vs N, and hot-swap latency (publish → applied at the
//! next decision frame). Emits BENCH_checkpoint.json, next to the other
//! BENCH_*.json baselines in ci.sh.
//!
//! Runs fully offline on the native backend with the built-in RL demo
//! manifest and the synthetic device profile. MACCI_BENCH_MS-bounded.

use macci::coordinator::decision::{ActorDecision, DecisionMaker};
use macci::env::scenario::ScenarioConfig;
use macci::profiles::DeviceProfile;
use macci::rl::checkpoint::{self, PolicySnapshot};
use macci::rl::mahppo::{MahppoTrainer, TrainConfig};
use macci::runtime::artifacts::ArtifactStore;
use macci::util::bench::{black_box, Bench};
use macci::util::json::Json;

fn trainer_for(store: &ArtifactStore, n: usize) -> MahppoTrainer {
    let scenario = ScenarioConfig {
        n_ues: n,
        lambda_tasks: 20.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 256,
        n_envs: 2,
        seed: 13,
        ..Default::default()
    };
    MahppoTrainer::new(store, &DeviceProfile::synthetic(), scenario, cfg).unwrap()
}

fn main() {
    let store = ArtifactStore::native_demo();
    let mut b = Bench::new("checkpoint");
    let mut json = Json::obj();

    // -- encode / decode throughput + size, across the N sweep ----------
    let mut sizes: Vec<(String, usize)> = Vec::new();
    for &n in &[3usize, 5, 8] {
        let trainer = trainer_for(&store, n);
        let cp = trainer.checkpoint();
        let bytes = checkpoint::encode(&cp).unwrap();
        println!("N = {n}: checkpoint is {} bytes", bytes.len());
        sizes.push((format!("n{n}"), bytes.len()));
        b.run(&format!("encode_n{n}"), || {
            black_box(checkpoint::encode(black_box(&cp)).unwrap());
        });
        b.run(&format!("decode_n{n}"), || {
            black_box(checkpoint::decode(black_box(&bytes)).unwrap());
        });
        json = json.set(&format!("checkpoint/size_n{n}"), bytes.len() as f64);
    }

    // -- hot-swap latency: publish + apply-at-next-frame vs plain frame --
    let n = 5;
    let trainer = trainer_for(&store, n);
    let snap = trainer.policy_snapshot();
    let mut dm = DecisionMaker::new(Box::new(ActorDecision::from_actors(
        trainer.actors,
        1.0,
        6,
    )));
    let handle = dm.policy_handle();
    let state = vec![0.3f32; 4 * n];
    b.run("decision_frame", || {
        black_box(dm.next_decision(black_box(&state)).unwrap());
    });
    b.run("publish_and_swap_frame", || {
        handle.publish(PolicySnapshot {
            version: 1,
            actors: snap.actors.clone(),
        });
        black_box(dm.next_decision(black_box(&state)).unwrap());
    });

    // -- derived figures -> BENCH_checkpoint.json ------------------------
    let mut frame_ns = 0.0f64;
    let mut swap_frame_ns = 0.0f64;
    for r in b.results() {
        let mut entry = Json::obj()
            .set("mean_ns", r.mean_ns)
            .set("p99_ns", r.p99_ns);
        if let Some(nn) = r
            .name
            .strip_prefix("encode_")
            .or_else(|| r.name.strip_prefix("decode_"))
        {
            if let Some(&(_, size)) = sizes.iter().find(|(k, _)| k == nn) {
                let mb_per_s = size as f64 / (r.mean_ns / 1e9) / 1e6;
                entry = entry.set("mb_per_s", mb_per_s);
                println!("{:>28}: {:8.1} MB/s", r.name, mb_per_s);
            }
        }
        if r.name == "decision_frame" {
            frame_ns = r.mean_ns;
        }
        if r.name == "publish_and_swap_frame" {
            swap_frame_ns = r.mean_ns;
        }
        json = json.set(&format!("checkpoint/{}", r.name), entry);
    }
    let swap_overhead = (swap_frame_ns - frame_ns).max(0.0);
    println!(
        "swap latency: plain frame {:.1} µs, publish+swap frame {:.1} µs -> overhead {:.1} µs",
        frame_ns / 1e3,
        swap_frame_ns / 1e3,
        swap_overhead / 1e3
    );
    json = json.set("checkpoint/swap_overhead_ns", swap_overhead);
    json.write_file("BENCH_checkpoint.json").unwrap();
    println!("wrote BENCH_checkpoint.json");
}
