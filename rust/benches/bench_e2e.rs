//! End-to-end benches: one full training frame (decision + env step) and
//! one PPO round — the unit costs of every Fig. 8-13 run — plus the
//! collaborative-inference serving path (real CNN artifacts).

use macci::coordinator::inference::CollabPipeline;
use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::ScenarioConfig;
use macci::exp::fig4::smooth_images;
use macci::profiles::DeviceProfile;
use macci::rl::mahppo::{MahppoTrainer, TrainConfig};
use macci::runtime::artifacts::ArtifactStore;
use macci::util::bench::{black_box, Bench};

fn main() {
    let store = match ArtifactStore::open("artifacts") {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping e2e benches: {e:#}");
            return;
        }
    };
    let mut b = Bench::new("e2e");

    // full training-frame cost: policy inference x5 + critic + env step,
    // measured through a real trainer by running short train() bursts
    let profile =
        DeviceProfile::load_or_synthetic("artifacts/profiles/resnet18.json").expect("device profile");
    let scenario = ScenarioConfig {
        n_ues: 5,
        lambda_tasks: 1e9,
        max_frames: usize::MAX,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 64,
        minibatch: 256, // never reached inside one frame burst
        ..Default::default()
    };
    let _ = cfg;

    let mut env = MultiAgentEnv::new(profile.clone(), scenario.clone(), 1).unwrap();
    let mut trainer = MahppoTrainer::new(
        &store,
        &profile,
        scenario,
        TrainConfig {
            buffer_size: 256,
            minibatch: 256,
            reuse: 10,
            ..Default::default()
        },
    )
    .unwrap();
    // warm the executable cache
    let _ = trainer.train(8).unwrap();

    b.run("train_frame_n5", || {
        // 16 frames per iteration to amortize the Bench overhead; the
        // per-frame figure is this / 16 (buffer fills trigger PPO rounds
        // every 256 frames and are included pro-rata, as in real runs)
        black_box(trainer.train(16).unwrap());
    });

    let actions: macci::env::Action = (0..5)
        .map(|i| macci::env::HybridAction::new(2, i % 2, 1.0, 1.0))
        .collect();
    b.run("env_frame_only_n5", || {
        black_box(env.step(black_box(&actions)));
    });

    // serving path on real CNN artifacts
    if let Ok(pipeline) = CollabPipeline::load(&store, "resnet18") {
        let img = &smooth_images(1, pipeline.meta.input_hw, 5)[0];
        b.run("serve_local_full", || {
            black_box(pipeline.infer_local(black_box(img)).unwrap());
        });
        for p in [1usize, 2, 4] {
            b.run(&format!("serve_split_p{p}"), || {
                black_box(pipeline.infer_split(black_box(img), p).unwrap());
            });
        }
    }

    b.report();
}
