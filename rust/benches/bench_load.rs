//! Massive-fleet load bench: the sharded reactor serving core under a
//! trace-driven loopback fleet. Emits BENCH_load.json.
//!
//! Grid: fleet size (1k and `MACCI_BENCH_LOAD_UES`, default 10k UEs) ×
//! shard count (1 / 2 / 4). Each cell binds a fresh reactor, spawns the
//! shard server loops (per-UE slim decisions, partial-pool ticks — the
//! fleet-serving configuration) and drives the fleet for
//! `MACCI_BENCH_MS` per cell through multiplexed stations with one
//! churning station. The figures of merit are decisions/s, offloads/s
//! and the p50/p99/p999 report→decision latency, with every dropped
//! downlink counted (`downlink_drops` — satellite of ISSUE 8's drop
//! audit), never silent.

use std::hint::black_box;
use std::sync::Arc;
use std::time::{Duration, Instant};

use macci::coordinator::decision::{DecisionMaker, StaticDecision};
use macci::coordinator::executor::{ExecutorConfig, OffloadCompute, SyntheticCompute};
use macci::coordinator::protocol::{Downlink, FrameDecision};
use macci::coordinator::server::ServerConfig;
use macci::coordinator::shard::{spawn_shards, ShardMap};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::coordinator::wire::{
    encode_decision_body, encode_down_to_raw, encode_frame, encode_frame_append,
    encode_frame_into, Frame,
};
use macci::env::HybridAction;
use macci::loadgen::{run_fleet, ArrivalMode, FleetConfig};
use macci::transport::reactor::{ReactorConfig, TcpReactor};
use macci::util::json::Json;

const ITEM_COST: Duration = Duration::from_micros(50);

struct Cell {
    decisions_per_s: f64,
    offloads_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
    reports_sent: usize,
    decisions_received: usize,
    reconnects: usize,
    frames: usize,
    downlink_drops: usize,
    uplink_drops: usize,
}

fn run_one(n_ues: usize, n_shards: usize, run: Duration) -> Cell {
    let map = ShardMap::new(n_ues, n_shards);
    let (reactor, transports) =
        TcpReactor::bind("127.0.0.1:0", ReactorConfig::new(n_ues, n_shards)).unwrap();
    let addr = reactor.local_addr();

    let compute = Arc::new(SyntheticCompute::new(ITEM_COST)) as Arc<dyn OffloadCompute>;
    let shards: Vec<_> = transports
        .into_iter()
        .enumerate()
        .map(|(shard, t)| {
            let len = map.slice_of(shard).unwrap().1;
            let pool = StatePool::new(
                len,
                StateNorm {
                    lambda_tasks: 10.0,
                    frame_s: 0.5,
                    max_bits: 1e6,
                    d_max: 100.0,
                },
            );
            let dm = DecisionMaker::new(Box::new(StaticDecision::new(vec![
                HybridAction::new(0, 0, 0.0, 1.0);
                len
            ])));
            (t, pool, dm)
        })
        .collect();
    let mk_cfg = |_shard: usize, len: usize| {
        let mut cfg = ServerConfig::new(len, Duration::from_millis(25), usize::MAX);
        cfg.per_ue_decisions = true; // O(n) broadcast bytes, not O(n²)
        cfg.exit_when_empty = false; // churn gaps must not stop the shard
        cfg.decide_on_partial = true; // a 10k pool is never complete
        cfg.drain_limit = 1024;
        cfg.exec = ExecutorConfig {
            workers: 1, // the bench host may be single-core
            max_wait: Duration::from_micros(100),
            ..ExecutorConfig::default()
        };
        cfg
    };
    let (handles, _policy) = spawn_shards(&map, mk_cfg, shards, Some(compute)).unwrap();

    let fleet = FleetConfig {
        addr,
        n_ues,
        n_stations: (n_ues / 512).clamp(1, 24),
        mode: ArrivalMode::Open,
        duration: run,
        report_interval: Duration::from_millis(100),
        offload_every: 8,
        churn_period: Some(run / 2),
        churn_stations: 1,
    };
    let stats = run_fleet(&fleet).unwrap();

    // stopping the reactor closes the shard uplinks; the loops drain and
    // exit, surfacing their per-shard counters
    let rstats = reactor.stop();
    let mut frames = 0usize;
    let mut downlink_drops = 0usize;
    for h in handles {
        let s = h.join();
        frames += s.frames;
        downlink_drops += s.downlink_drops;
    }

    assert!(stats.decisions_received > 0, "fleet never saw a decision");
    assert!(frames > 0, "no shard issued a frame");

    Cell {
        decisions_per_s: stats.decisions_per_s(),
        offloads_per_s: stats.offloads_per_s(),
        p50_ms: stats.p50_ms(),
        p99_ms: stats.p99_ms(),
        p999_ms: stats.p999_ms(),
        reports_sent: stats.reports_sent,
        decisions_received: stats.decisions_received,
        reconnects: stats.reconnects,
        frames,
        downlink_drops,
        uplink_drops: rstats.uplink_drops,
    }
}

/// Data-plane micro-gauges (DESIGN.md §Data-Plane): the allocating
/// encoder vs the reused-buffer `_into` path, and the per-subscriber
/// re-encode fan-out vs the single-encode + raw-stamp broadcast. Pure
/// CPU, no sockets — isolates what pooling buys before the fleet run
/// measures it end to end.
fn wire_gauges() -> Json {
    const SUBS: usize = 512; // one shard's slice of a 10k-UE broadcast
    const REPS: usize = 2_000;
    const FAN_REPS: usize = 20;

    let actions: std::sync::Arc<[HybridAction]> = (0..SUBS)
        .map(|i| HybridAction::new(i % 5, i % 4, 0.0, 1.0))
        .collect();
    let d = FrameDecision { frame: 1, actions };
    let joint = Frame::Down(Downlink::Decision(d.clone()));

    // allocating: a fresh Vec per frame (the pre-pooling encoder)
    let t0 = Instant::now();
    for _ in 0..REPS {
        black_box(encode_frame(&joint));
    }
    let alloc_per_s = REPS as f64 / t0.elapsed().as_secs_f64();

    // pooled: one reused buffer, allocation-free at steady state
    // (proven by tests/zero_alloc.rs; this gauge prices it)
    let mut buf = Vec::new();
    let t0 = Instant::now();
    for _ in 0..REPS {
        encode_frame_into(&joint, &mut buf);
        black_box(buf.as_slice());
    }
    let pooled_per_s = REPS as f64 / t0.elapsed().as_secs_f64();

    // fan-out, re-encode: every subscriber pays a full body encode
    let mut out = Vec::new();
    let t0 = Instant::now();
    for _ in 0..FAN_REPS {
        for ue in 0..SUBS {
            out.clear();
            encode_frame_append(
                &Frame::DownTo {
                    ue_id: ue,
                    down: Downlink::Decision(d.clone()),
                },
                &mut out,
            );
            black_box(out.as_slice());
        }
    }
    let reencode_per_s = (FAN_REPS * SUBS) as f64 / t0.elapsed().as_secs_f64();

    // fan-out, single-encode: body bytes once, then a stamp (copy + CRC)
    // per subscriber — the reactor's broadcast path
    let mut body = Vec::new();
    let t0 = Instant::now();
    for _ in 0..FAN_REPS {
        body.clear();
        let tag = encode_decision_body(d.frame, &d.actions, &mut body);
        for ue in 0..SUBS {
            out.clear();
            encode_down_to_raw(ue, tag, &body, &mut out);
            black_box(out.as_slice());
        }
    }
    let single_per_s = (FAN_REPS * SUBS) as f64 / t0.elapsed().as_secs_f64();

    println!(
        "  wire: encode alloc {alloc_per_s:>10.0}/s vs pooled {pooled_per_s:>10.0}/s \
         ({:.2}x) | fan-out re-encode {reencode_per_s:>9.0}/s vs single-encode \
         {single_per_s:>9.0}/s ({:.2}x)",
        pooled_per_s / alloc_per_s,
        single_per_s / reencode_per_s
    );
    Json::obj()
        .set("encode_alloc_frames_per_s", alloc_per_s)
        .set("encode_pooled_frames_per_s", pooled_per_s)
        .set("encode_pooled_speedup", pooled_per_s / alloc_per_s)
        .set("fanout_reencode_frames_per_s", reencode_per_s)
        .set("fanout_single_encode_frames_per_s", single_per_s)
        .set("fanout_single_encode_speedup", single_per_s / reencode_per_s)
}

fn main() {
    let run = Duration::from_millis(macci::util::config::bench_ms(1500));
    let big = macci::util::config::bench_load_ues(10_000) as usize;
    let mut fleets = vec![1_000usize.min(big), big];
    fleets.dedup();

    println!(
        "load bench: {} ms/cell, fleets {:?}, shards [1, 2, 4], open-loop + 1 churning station",
        run.as_millis(),
        fleets
    );
    let mut json = Json::obj();
    json = json.set("wire", wire_gauges());
    for &n_ues in &fleets {
        for &shards in &[1usize, 2, 4] {
            let c = run_one(n_ues, shards, run);
            println!(
                "  {n_ues:>6} UEs × {shards} shards: {:>9.1} dec/s | {:>7.1} off/s | \
                 p50 {:>7.2} ms | p99 {:>7.2} ms | p99.9 {:>7.2} ms | drops {}",
                c.decisions_per_s, c.offloads_per_s, c.p50_ms, c.p99_ms, c.p999_ms,
                c.downlink_drops
            );
            json = json.set(
                &format!("load/ues{n_ues}_shards{shards}"),
                Json::obj()
                    .set("decisions_per_s", c.decisions_per_s)
                    .set("offloads_per_s", c.offloads_per_s)
                    .set("p50_ms", c.p50_ms)
                    .set("p99_ms", c.p99_ms)
                    .set("p999_ms", c.p999_ms)
                    .set("reports_sent", c.reports_sent)
                    .set("decisions_received", c.decisions_received)
                    .set("reconnects", c.reconnects)
                    .set("frames", c.frames)
                    .set("downlink_drops", c.downlink_drops)
                    .set("uplink_drops", c.uplink_drops),
            );
        }
    }
    json.write_file("BENCH_load.json").unwrap();
    println!("wrote BENCH_load.json");
}
