//! Collaborative serving over the threaded edge server: UE threads run the
//! front model segment + AE compression and ship real payloads to the edge,
//! where the offload-executor worker pool decodes and completes inference —
//! the paper's Fig. 1/2 workflow with actual CNN numerics (not the analytic
//! simulator). UEs whose static decision is b = 0 offload the raw input
//! instead, exercising the dynamic batcher through the `_full_b8` artifact.
//!
//! Reports per-stage latency, wire sizes, throughput, split-vs-local top-1
//! agreement, and the executor's queue/batching counters.
//!
//! Run: `cargo run --release --example collab_serving -- [model] [n_ues] [tasks_per_ue] [workers]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use macci::coordinator::decision::{DecisionMaker, StaticDecision};
use macci::coordinator::executor::{OffloadCompute, PipelineCompute};
use macci::coordinator::inference::{argmax, CollabPipeline};
use macci::coordinator::protocol::{Downlink, OffloadRequest, UeStateReport, Uplink};
use macci::coordinator::server::{EdgeServer, ServerConfig};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::env::HybridAction;
use macci::exp::fig4::smooth_images;
use macci::runtime::artifacts::ArtifactStore;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "resnet18".into());
    let n_ues: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(3);
    let tasks: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(8);
    let workers: usize = args.get(4).and_then(|s| s.parse().ok()).unwrap_or(4);

    let store = ArtifactStore::open("artifacts")?;
    // the server's compute: pipeline + b8 batch runner, shared by the
    // worker pool; UEs load their own front halves (shares compiled
    // executables through the runtime cache)
    let server_compute = Arc::new(PipelineCompute::load(&store, &model)?);
    let num_points = server_compute.pipeline().num_points();
    let hw = server_compute.pipeline().meta.input_hw;

    let pool = StatePool::new(
        n_ues,
        StateNorm {
            lambda_tasks: tasks as f64,
            frame_s: 0.5,
            max_bits: 1.2e6,
            d_max: 100.0,
        },
    );
    // static decision: UE i rotates through raw offload (b = 0) and the
    // split points (b = 1..=P)
    let actions: Vec<HybridAction> = (0..n_ues)
        .map(|i| HybridAction::new(i % (num_points + 1), i % 2, 1.0, 1.0))
        .collect();
    let decisions = DecisionMaker::new(Box::new(StaticDecision::new(actions.clone())));
    let mut cfg = ServerConfig::new(n_ues, Duration::from_millis(20), 10_000);
    cfg.exec.workers = workers;
    let max_batch = cfg.exec.max_batch;
    let server_compute = Some(server_compute as Arc<dyn OffloadCompute>);
    let (server, mut downlinks) = EdgeServer::spawn(cfg, pool, decisions, server_compute)?;

    println!(
        "=== collaborative serving: {model}, {n_ues} UEs x {tasks} tasks, {workers} workers ==="
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (ue, rx) in downlinks.drain(..).enumerate() {
        let uplink = server.uplink.clone();
        let images = smooth_images(tasks, hw, 100 + ue as u64);
        let split_point = actions[ue].b;
        // local reference logits for agreement checking are computed by
        // the UE before offloading (demo-only; a real UE wouldn't)
        let pipeline = CollabPipeline::load(&store, &model)?;
        let builder = std::thread::Builder::new().name(format!("ue-{ue}"));
        handles.push(builder.spawn(move || -> Result<(usize, usize, f64, f64, usize)> {
            let mut agree = 0usize;
            let mut done = 0usize;
            let mut ue_compute = 0.0f64;
            let mut wire_bits = 0usize;
            let mut rtt = 0.0f64;
            uplink.send(Uplink::Report(UeStateReport {
                ue_id: ue,
                tasks_left: tasks as u64,
                compute_left_s: 0.0,
                offload_left_bits: 0.0,
                distance_m: 50.0,
            }))?;
            for (task, img) in images.iter().enumerate() {
                let (payload, calibration) = if split_point == 0 {
                    // raw offload: ship the image itself (batched edge-side)
                    let bytes: Vec<u8> = img.iter().flat_map(|v| v.to_le_bytes()).collect();
                    (bytes, None)
                } else {
                    let (encoded, timing) = pipeline.ue_half(img, split_point)?;
                    ue_compute += timing.ue_side_s();
                    (encoded.to_wire()?, Some((encoded.lo, encoded.hi)))
                };
                wire_bits += payload.len() * 8;
                let sent = Instant::now();
                uplink.send(Uplink::Offload(OffloadRequest {
                    ue_id: ue,
                    task_id: task as u64,
                    b: split_point,
                    payload,
                    calibration,
                }))?;
                // await our result (ignore decision broadcasts)
                loop {
                    match rx.recv_timeout(Duration::from_secs(30))? {
                        Downlink::Result(res) => {
                            rtt += sent.elapsed().as_secs_f64();
                            let local = pipeline.infer_local(img)?;
                            if argmax(&res.logits) == argmax(&local) {
                                agree += 1;
                            }
                            done += 1;
                            break;
                        }
                        Downlink::Decision(_) => continue,
                        Downlink::Error { task_id, error } => {
                            anyhow::bail!("task {task_id} failed at the edge: {error}")
                        }
                        Downlink::Shutdown => anyhow::bail!("server shut down early"),
                    }
                }
            }
            uplink.send(Uplink::Goodbye { ue_id: ue })?;
            Ok((done, agree, ue_compute, rtt, wire_bits))
        })?);
    }

    let mut total_done = 0;
    let mut total_agree = 0;
    let mut total_ue = 0.0;
    let mut total_rtt = 0.0;
    let mut total_bits = 0usize;
    for h in handles {
        let (done, agree, ue_s, rtt, bits) = h.join().expect("ue thread")?;
        total_done += done;
        total_agree += agree;
        total_ue += ue_s;
        total_rtt += rtt;
        total_bits += bits;
    }
    let stats = server.join();
    let wall = t0.elapsed().as_secs_f64();

    println!("served {total_done} tasks in {wall:.2}s -> {:.1} req/s", total_done as f64 / wall);
    println!(
        "per-task: UE half {:.2} ms | wire {:.1} kbit | round-trip {:.2} ms",
        total_ue / total_done as f64 * 1e3,
        total_bits as f64 / total_done as f64 / 1e3,
        total_rtt / total_done as f64 * 1e3
    );
    println!(
        "edge: {} offloads served ({} feature / {} raw, {} errors), {:.2} ms avg edge compute",
        stats.offloads_served,
        stats.feature_offloads,
        stats.raw_offloads,
        stats.offload_errors,
        stats.edge_compute_s / stats.offloads_served.max(1) as f64 * 1e3
    );
    if workers > 0 {
        println!(
            "executor: peak queue {} | mean queue wait {:.2} ms | {} batches, occupancy {:.0}%",
            stats.exec.max_queue_depth,
            stats.exec.mean_queue_wait_s() * 1e3,
            stats.exec.batches,
            stats.exec.batch_occupancy(max_batch) * 100.0
        );
    }
    println!("split-vs-local top-1 agreement: {total_agree}/{total_done}");
    assert_eq!(total_done, n_ues * tasks, "all tasks must complete");
    Ok(())
}
