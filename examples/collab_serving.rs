//! Collaborative serving over the threaded edge server: UE threads run the
//! front model segment + AE compression and ship real payloads to the edge
//! thread, which decodes and completes inference — the paper's Fig. 1/2
//! workflow with actual CNN numerics (not the analytic simulator).
//!
//! Reports per-stage latency, wire sizes, throughput, and split-vs-local
//! top-1 agreement.
//!
//! Run: `cargo run --release --example collab_serving -- [model] [n_ues] [tasks_per_ue]`

use std::time::{Duration, Instant};

use anyhow::Result;
use macci::coordinator::decision::{DecisionMaker, StaticDecision};
use macci::coordinator::inference::CollabPipeline;
use macci::coordinator::protocol::{Downlink, OffloadRequest, UeStateReport, Uplink};
use macci::coordinator::server::{EdgeServer, ServerConfig};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::env::HybridAction;
use macci::exp::fig4::smooth_images;
use macci::runtime::artifacts::ArtifactStore;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "resnet18".into());
    let n_ues: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(3);
    let tasks: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(8);

    let store = ArtifactStore::open("artifacts")?;
    // one pipeline for the server, one per-UE front half (shares compiled
    // executables through the runtime cache)
    let server_pipeline = CollabPipeline::load(&store, &model)?;
    let ue_pipeline = CollabPipeline::load(&store, &model)?;
    let num_points = ue_pipeline.num_points();
    let hw = ue_pipeline.meta.input_hw;

    let pool = StatePool::new(
        n_ues,
        StateNorm {
            lambda_tasks: tasks as f64,
            frame_s: 0.5,
            max_bits: 1.2e6,
            d_max: 100.0,
        },
    );
    // static decision: UE i splits at point (i mod 4) + 1
    let actions: Vec<HybridAction> = (0..n_ues)
        .map(|i| HybridAction::new(1 + (i % num_points), i % 2, 1.0, 1.0))
        .collect();
    let decisions = DecisionMaker::new(Box::new(StaticDecision {
        actions: actions.clone(),
    }));
    let cfg = ServerConfig {
        n_ues,
        decision_interval: Duration::from_millis(20),
        max_frames: 10_000,
    };
    let (server, mut downlinks) = EdgeServer::spawn(cfg, pool, decisions, Some(server_pipeline))?;

    println!("=== collaborative serving: {model}, {n_ues} UEs x {tasks} tasks ===");
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (ue, rx) in downlinks.drain(..).enumerate() {
        let uplink = server.uplink.clone();
        let images = smooth_images(tasks, hw, 100 + ue as u64);
        let split_point = actions[ue].b;
        // local reference logits for agreement checking are computed by
        // the UE before offloading (demo-only; a real UE wouldn't)
        let pipeline = CollabPipeline::load(&store, &model)?;
        handles.push(std::thread::spawn(move || -> Result<(usize, usize, f64, f64, usize)> {
            let mut agree = 0usize;
            let mut done = 0usize;
            let mut ue_compute = 0.0f64;
            let mut wire_bits = 0usize;
            let mut rtt = 0.0f64;
            uplink.send(Uplink::Report(UeStateReport {
                ue_id: ue,
                tasks_left: tasks as u64,
                compute_left_s: 0.0,
                offload_left_bits: 0.0,
                distance_m: 50.0,
            }))?;
            for (task, img) in images.iter().enumerate() {
                let (encoded, timing) = pipeline.ue_half(img, split_point)?;
                ue_compute += timing.ue_side_s();
                wire_bits += encoded.wire_bits();
                let sent = Instant::now();
                uplink.send(Uplink::Offload(OffloadRequest {
                    ue_id: ue,
                    task_id: task as u64,
                    b: split_point,
                    payload: encoded.to_wire()?,
                    calibration: Some((encoded.lo, encoded.hi)),
                }))?;
                // await our result (ignore decision broadcasts)
                loop {
                    match rx.recv_timeout(Duration::from_secs(30))? {
                        Downlink::Result(res) => {
                            rtt += sent.elapsed().as_secs_f64();
                            let local = pipeline.infer_local(img)?;
                            let am = |v: &[f32]| {
                                v.iter()
                                    .enumerate()
                                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                                    .map(|(i, _)| i)
                                    .unwrap()
                            };
                            if am(&res.logits) == am(&local) {
                                agree += 1;
                            }
                            done += 1;
                            break;
                        }
                        Downlink::Decision(_) => continue,
                        Downlink::Shutdown => anyhow::bail!("server shut down early"),
                    }
                }
            }
            uplink.send(Uplink::Goodbye { ue_id: ue })?;
            Ok((done, agree, ue_compute, rtt, wire_bits))
        }));
    }

    let mut total_done = 0;
    let mut total_agree = 0;
    let mut total_ue = 0.0;
    let mut total_rtt = 0.0;
    let mut total_bits = 0usize;
    for h in handles {
        let (done, agree, ue_s, rtt, bits) = h.join().expect("ue thread")?;
        total_done += done;
        total_agree += agree;
        total_ue += ue_s;
        total_rtt += rtt;
        total_bits += bits;
    }
    let stats = server.join();
    let wall = t0.elapsed().as_secs_f64();

    println!("served {total_done} tasks in {wall:.2}s -> {:.1} req/s", total_done as f64 / wall);
    println!(
        "per-task: UE half {:.2} ms | wire {:.1} kbit | round-trip {:.2} ms",
        total_ue / total_done as f64 * 1e3,
        total_bits as f64 / total_done as f64 / 1e3,
        total_rtt / total_done as f64 * 1e3
    );
    println!(
        "edge: {} offloads served ({} feature / {} raw), {:.2} ms avg edge compute",
        stats.offloads_served,
        stats.feature_offloads,
        stats.raw_offloads,
        stats.edge_compute_s / stats.offloads_served.max(1) as f64 * 1e3
    );
    println!("split-vs-local top-1 agreement: {total_agree}/{total_done}");
    assert_eq!(total_done, n_ues * tasks, "all tasks must complete");
    Ok(())
}
