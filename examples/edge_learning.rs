//! End-to-end edge-learning driver — the repository's headline validation
//! run (recorded in EXPERIMENTS.md).
//!
//! Trains the full MAHPPO stack (N = 5 UEs, ResNet18 profile) with ALL
//! network compute flowing through the artifact executables on the
//! configured backend (native interpreter by default, PJRT with
//! `--features xla-pjrt`). Experience comes from the vectorized rollout
//! engine: `n_envs` parallel environment lanes batched through one forward
//! per actor (`n_envs = 1` is the classic serial loop). Logs the reward
//! curve, then evaluates the learned policy against the Local and Random
//! baselines on a fresh eval-seeded env and prints the overhead-savings
//! summary.
//!
//! Run: `cargo run --release --example edge_learning -- [frames] [n_ues] [n_envs]`

use anyhow::Result;
use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::ScenarioConfig;
use macci::profiles::DeviceProfile;
use macci::rl::baselines::{evaluate_policy, BaselinePolicy, PolicyKind};
use macci::rl::mahppo::{MahppoTrainer, TrainConfig};
use macci::runtime::artifacts::ArtifactStore;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let frames: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8_000);
    let n_ues: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(5);
    let n_envs: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(4);

    let store = ArtifactStore::open("artifacts")?;
    let profile = DeviceProfile::load_or_synthetic("artifacts/profiles/resnet18.json")?;
    let scenario = ScenarioConfig {
        n_ues,
        lambda_tasks: 200.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        n_envs,
        ..Default::default()
    };

    println!("=== edge learning: MAHPPO, N = {n_ues}, {frames} frames, E = {n_envs} lanes ===");
    let mut trainer = MahppoTrainer::new(&store, &profile, scenario.clone(), cfg)?;
    let report = trainer.train(frames)?;

    // reward curve (sampled)
    println!("\nreward curve (episode -> cumulative reward, smoothed):");
    let curve = report.episode_rewards.smoothed(5);
    let stride = (curve.ys.len() / 16).max(1);
    for i in (0..curve.ys.len()).step_by(stride) {
        println!("  ep {:>4}  {:>10.2}  {}", i, curve.ys[i], bar(curve.ys[i], &curve.ys));
    }
    println!(
        "{} episodes over {} frames in {:.1}s ({:.0} frames/s over {} lanes, incl. {} PPO rounds)",
        report.episodes,
        report.frames,
        report.wall_s,
        report.frames as f64 / report.wall_s,
        trainer.n_envs(),
        report.value_losses.ys.len(),
    );

    // evaluation vs baselines (fresh eval-seeded env; training untouched)
    let mut eval_sc = scenario.clone();
    eval_sc.eval_mode = true;
    let ours = trainer.evaluate_on(eval_sc.clone(), 3)?;

    let mut env = MultiAgentEnv::new(profile.clone(), eval_sc, 11)?;
    let mut local = BaselinePolicy::new(PolicyKind::Local, 0);
    let base = evaluate_policy(&mut local, &mut env, 1)?;
    let mut random = BaselinePolicy::new(PolicyKind::Random, 1);
    let rand = evaluate_policy(&mut random, &mut env, 1)?;

    println!("\n               latency (ms)   energy (mJ)   reward");
    println!("  MAHPPO       {:>10.1}   {:>10.1}   {:>8.2}", ours.avg_latency * 1e3, ours.avg_energy * 1e3, ours.avg_reward);
    println!("  Local        {:>10.1}   {:>10.1}   {:>8.2}", base.avg_latency * 1e3, base.avg_energy * 1e3, base.avg_reward);
    println!("  Random       {:>10.1}   {:>10.1}   {:>8.2}", rand.avg_latency * 1e3, rand.avg_energy * 1e3, rand.avg_reward);
    println!(
        "\nsavings vs local: latency {:+.0}% | energy {:+.0}%  (paper @N=3: -56% / -72%)",
        (ours.avg_latency / base.avg_latency - 1.0) * 100.0,
        (ours.avg_energy / base.avg_energy - 1.0) * 100.0
    );
    Ok(())
}

fn bar(v: f64, all: &[f64]) -> String {
    let lo = all.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = all.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let frac = if hi > lo { (v - lo) / (hi - lo) } else { 1.0 };
    "#".repeat(1 + (frac * 40.0) as usize)
}
