//! Feature-compression walkthrough (paper Sec. 2): runs a real image
//! through the split backbone at every partition point and compares the
//! lightweight autoencoder (Pallas conv1x1 + quant kernels, AOT) against
//! the JALAD baseline (8-bit quant + Huffman, native Rust) on:
//!   compression rate, payload size, reconstruction error, top-1 agreement.
//!
//! Run: `cargo run --release --example compression_demo -- [model]`

use anyhow::Result;
use macci::compress::jalad::JaladCompressor;
use macci::coordinator::inference::CollabPipeline;
use macci::exp::fig4::smooth_images;
use macci::runtime::artifacts::ArtifactStore;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let store = ArtifactStore::open("artifacts")?;
    let pipeline = CollabPipeline::load(&store, &model)?;
    let jalad = JaladCompressor::new();
    let images = smooth_images(4, pipeline.meta.input_hw, 7);

    println!("=== feature compression on {model} ({} classes) ===", pipeline.meta.num_classes);
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "point", "feat kbit", "AE kbit", "AE rate", "JALAD rate", "AE err", "agree"
    );

    for p in 1..=pipeline.num_points() {
        let mut ae_bits = 0.0;
        let mut feat_bits = 0.0;
        let mut jalad_rate = 0.0;
        let mut err = 0.0f64;
        let mut agree = 0usize;
        for img in &images {
            let feature = pipeline.front_feature(img, p)?;
            feat_bits += (feature.len() * 32) as f64;
            jalad_rate += jalad.rate(&feature);

            let (encoded, mut timing) = pipeline.ue_half(img, p)?;
            ae_bits += encoded.wire_bits() as f64;
            let logits = pipeline.edge_half(&encoded, p, &mut timing)?;
            let local = pipeline.infer_local(img)?;
            let am = |v: &[f32]| {
                v.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            };
            if am(&logits) == am(&local) {
                agree += 1;
            }
            // reconstruction error via decode
            let restored = decode_roundtrip(&pipeline, img, p)?;
            let n = feature.len() as f64;
            err += feature
                .iter()
                .zip(&restored)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / n;
        }
        let n = images.len() as f64;
        println!(
            "{:>6} {:>12.1} {:>12.1} {:>11.1}x {:>11.1}x {:>10.4} {:>7}/{}",
            format!("p{p}"),
            feat_bits / n / 1e3,
            ae_bits / n / 1e3,
            feat_bits / ae_bits,
            jalad_rate / n,
            (err / n).sqrt(),
            agree,
            images.len()
        );
    }
    println!("\n(AE rate = paper Eq. 3 R = ch*32/(ch'*bits); JALAD measured via Huffman on 8-bit codes)");
    Ok(())
}

fn decode_roundtrip(pipeline: &CollabPipeline, img: &[f32], p: usize) -> Result<Vec<f32>> {
    let (encoded, _t) = pipeline.ue_half(img, p)?;
    pipeline.decode_feature(&encoded, p)
}
