//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! 1. open the artifact store (native backend by default; synthesizes the
//!    built-in RL demo manifest when no artifacts exist on disk),
//! 2. simulate the multi-UE environment under a baseline policy,
//! 3. train a small MAHPPO agent for a few hundred frames,
//! 4. compare the learned policy against full-local inference.
//!
//! Run: `cargo run --release --example quickstart` — works fully offline;
//! `make artifacts` + `--features xla-pjrt` switches to compiled HLO.

use anyhow::Result;
use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::ScenarioConfig;
use macci::profiles::DeviceProfile;
use macci::rl::baselines::{evaluate_policy, BaselinePolicy, PolicyKind};
use macci::rl::mahppo::{MahppoTrainer, TrainConfig};
use macci::runtime::artifacts::ArtifactStore;

fn main() -> Result<()> {
    // 1. artifacts (network layouts, profiles, trained weights)
    let store = ArtifactStore::open("artifacts")?;
    println!("backend: {}", store.backend_name());

    let profile = DeviceProfile::load_or_synthetic("artifacts/profiles/resnet18.json")?;
    println!(
        "device profile: full-local inference = {:.1} ms / {:.1} mJ",
        profile.full_local_t * 1e3,
        profile.full_local_e * 1e3
    );

    // 2. the environment under the Local baseline
    let scenario = ScenarioConfig {
        n_ues: 3,
        lambda_tasks: 50.0,
        eval_tasks: 50,
        eval_mode: true,
        ..Default::default()
    };
    let mut env = MultiAgentEnv::new(profile.clone(), scenario.clone(), 1)?;
    let mut local = BaselinePolicy::new(PolicyKind::Local, 0);
    let base = evaluate_policy(&mut local, &mut env, 1)?;
    println!(
        "local baseline: {:.1} ms / {:.1} mJ per task",
        base.avg_latency * 1e3,
        base.avg_energy * 1e3
    );

    // 3. train MAHPPO briefly (N = 3)
    let mut train_scenario = scenario.clone();
    train_scenario.eval_mode = false;
    let mut trainer = MahppoTrainer::new(
        &store,
        &profile,
        train_scenario,
        TrainConfig {
            buffer_size: 512,
            minibatch: 256,
            ..Default::default()
        },
    )?;
    println!("training MAHPPO for 2000 frames ...");
    let report = trainer.train(2000)?;
    println!(
        "  {} episodes, final episode reward {:.2} ({:.1} s wall)",
        report.episodes,
        report.final_reward(),
        report.wall_s
    );

    // 4. greedy evaluation vs the baseline (fresh eval-seeded env)
    let mut eval_sc = scenario.clone();
    eval_sc.eval_mode = true;
    eval_sc.eval_tasks = 50;
    let ours = trainer.evaluate_on(eval_sc, 1)?;
    println!(
        "MAHPPO:        {:.1} ms / {:.1} mJ per task",
        ours.avg_latency * 1e3,
        ours.avg_energy * 1e3
    );
    println!(
        "savings vs local: latency {:+.0}%, energy {:+.0}%",
        (1.0 - ours.avg_latency / base.avg_latency) * 100.0,
        (1.0 - ours.avg_energy / base.avg_energy) * 100.0
    );
    Ok(())
}
