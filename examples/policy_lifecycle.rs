//! End-to-end policy lifecycle: **train → save → restart → serve from
//! checkpoint → learn online → hot-swap**.
//!
//! 1. Trains a small MAHPPO agent and saves the full trainer state to a
//!    versioned, CRC-guarded checkpoint file (`rl::checkpoint`).
//! 2. Simulates a process restart: reloads the checkpoint and proves the
//!    resume seam is **bit-exact** — the original trainer and the resumed
//!    one produce byte-identical parameters after the same extra frames.
//! 3. Serves the checkpointed policy from the threaded edge server while
//!    the online learner (`coordinator::learner`) consumes serving
//!    telemetry, runs PPO off the serving thread, and hot-swaps refreshed
//!    policies between decision frames — verifying zero missed broadcasts
//!    and that served decisions actually changed.
//!
//! Run: `cargo run --release --example policy_lifecycle -- [train_frames] [serve_frames]`

use std::time::Duration;

use anyhow::{ensure, Result};
use macci::coordinator::decision::{ActorDecision, DecisionMaker};
use macci::coordinator::learner::{self, LearnerConfig};
use macci::coordinator::protocol::Uplink;
use macci::coordinator::server::{drive_env_ues, EdgeServer, ServerConfig};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::env::mdp::MultiAgentEnv;
use macci::env::scenario::ScenarioConfig;
use macci::env::HybridAction;
use macci::profiles::DeviceProfile;
use macci::rl::checkpoint;
use macci::rl::mahppo::{MahppoTrainer, TrainConfig};
use macci::runtime::artifacts::ArtifactStore;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let train_frames: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let serve_frames: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let store = ArtifactStore::open("artifacts")?;
    let profile = DeviceProfile::load_or_synthetic("artifacts/profiles/resnet18.json")?;
    let scenario = ScenarioConfig {
        n_ues: 5,
        lambda_tasks: 20.0,
        ..Default::default()
    };
    let cfg = TrainConfig {
        buffer_size: 256,
        minibatch: 128, // N = 5 ships a 128-batch update artifact
        reuse: 2,
        n_envs: 2,
        lr: 3e-4,
        seed: 7,
        ..Default::default()
    };
    let n = scenario.n_ues;

    // ---- 1. train + save -------------------------------------------------
    println!("=== policy lifecycle: N = {n} ===");
    println!("[1/3] training {train_frames} frames...");
    let mut trainer = MahppoTrainer::new(&store, &profile, scenario.clone(), cfg)?;
    let report = trainer.train(train_frames)?;
    println!(
        "      {} episodes, final reward {:.2}",
        report.episodes,
        report.final_reward()
    );
    let dir = std::env::temp_dir().join("macci_policy_lifecycle");
    std::fs::create_dir_all(&dir)?;
    let ckpt_path = dir.join("policy.ckpt");
    trainer.save(&ckpt_path)?;
    let bytes = std::fs::metadata(&ckpt_path)?.len();
    println!("      saved full trainer state: {} ({bytes} bytes)", ckpt_path.display());

    // ---- 2. "restart": reload and prove bit-exact resume ----------------
    println!("[2/3] restart: resuming from the checkpoint...");
    let mut resumed = MahppoTrainer::load(&store, &ckpt_path)?;
    let more = 256;
    trainer.train(more)?;
    resumed.train(more)?;
    for (u, (a, b)) in trainer.actors.iter().zip(&resumed.actors).enumerate() {
        ensure!(
            a.params == b.params,
            "actor {u} diverged after resume — the state seam is incomplete"
        );
    }
    ensure!(trainer.critic.params == resumed.critic.params, "critic diverged");
    println!("      resume is bit-exact: +{more} frames on both paths -> identical params");

    // ---- 3. serve from the checkpoint, learn online, hot-swap -----------
    println!("[3/3] serving {serve_frames} decision frames with online learning...");
    let cp = checkpoint::load(&ckpt_path)
        .map_err(|e| anyhow::anyhow!("reloading {}: {e}", ckpt_path.display()))?;
    let decisions = DecisionMaker::new(Box::new(ActorDecision::from_trainer_checkpoint(
        &store, &cp,
    )?));
    let policy_handle = decisions.policy_handle();
    let pool = StatePool::new(
        n,
        StateNorm {
            lambda_tasks: scenario.lambda_tasks,
            frame_s: scenario.frame_s,
            max_bits: profile.max_bits(),
            d_max: scenario.d_max,
        },
    );
    // 3 ms frames: the learner's first PPO round (triggered after one
    // buffer of telemetry, ~128 frames) has ample time to publish while
    // plenty of decision frames remain to observe the swap
    let mut server_cfg = ServerConfig::new(n, Duration::from_millis(3), serve_frames);
    let (telemetry_tx, telemetry_rx) = std::sync::mpsc::sync_channel(1024);
    server_cfg.telemetry = Some(telemetry_tx);
    let lcfg = LearnerConfig {
        lr: 5e-3, // deliberately hot so the swap visibly moves decisions
        reuse: 1, // one cheap PPO round per fill -> fastest publish
        ..LearnerConfig::for_store(&store, n)?
    };
    let learner = learner::spawn(
        &store,
        &profile,
        &scenario,
        lcfg,
        Some(&cp),
        telemetry_rx,
        policy_handle,
    )?;
    let (server, downlinks) = EdgeServer::spawn(server_cfg, pool, decisions, None)?;

    // drive the UEs from the analytic env; record every broadcast
    let mut env = MultiAgentEnv::new(profile.clone(), scenario.clone(), 11)?;
    let mut first_actions: Option<Vec<HybridAction>> = None;
    let mut changed_frames = 0usize;
    let mut first_change = None;
    let received = drive_env_ues(
        &server.uplink,
        &downlinks,
        &mut env,
        serve_frames,
        |frame, actions| {
            if let Some(first) = &first_actions {
                if first.as_slice() != actions {
                    changed_frames += 1;
                    first_change.get_or_insert(frame);
                }
            } else {
                first_actions = Some(actions.to_vec());
            }
        },
    )?;
    for ue in 0..n {
        let _ = server.uplink.send(Uplink::Goodbye { ue_id: ue });
    }
    let stats = server.join();
    let learner_stats = learner.join();

    let min_received = *received.iter().min().unwrap_or(&0);
    println!(
        "      {} decision frames broadcast; every UE received {min_received} (zero missed)",
        stats.frames
    );
    println!(
        "      online learner: {} telemetry frames -> {} PPO rounds -> {} published policies; {} swaps applied",
        learner_stats.frames, learner_stats.rounds, learner_stats.publishes, stats.policy_swaps
    );
    println!(
        "      served decisions changed in {changed_frames} frames (first at frame {:?})",
        first_change
    );

    // the acceptance bar: no broadcast lost to a swap, and the online
    // loop visibly moved the served policy
    ensure!(min_received == stats.frames, "a UE missed a broadcast");
    ensure!(stats.policy_swaps >= 1, "no policy swap was applied mid-serve");
    ensure!(
        changed_frames > 0,
        "online learning never changed a served decision"
    );
    println!("policy lifecycle OK: train -> save -> restart -> serve -> online swap");
    Ok(())
}
