//! Remote serving over loopback TCP: the edge server binds a real socket
//! and UE clients attach through `TcpClientTransport` — the same
//! handshake → report → decision → offload → result workflow a UE on
//! another machine would drive (README §Remote serving). Runs fully
//! offline on the synthetic offload compute; swap in `PipelineCompute`
//! for real model serving.
//!
//! One UE also ships a deliberately malformed feature offload
//! (calibration missing) to show the admission-time `Error` NACK.
//!
//! Run: `cargo run --release --example remote_serving -- [n_ues] [tasks_per_ue] [port]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use macci::coordinator::decision::{DecisionMaker, StaticDecision};
use macci::coordinator::executor::{OffloadCompute, SyntheticCompute};
use macci::coordinator::protocol::UeStateReport;
use macci::coordinator::server::{EdgeServer, ServerConfig};
use macci::coordinator::state_pool::{StateNorm, StatePool};
use macci::env::HybridAction;
use macci::transport::tcp::{TcpClientTransport, TcpServerTransport};
use macci::transport::ue::UeClient;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_ues: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let tasks: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(16);
    let port: u16 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(0);

    let compute = Arc::new(SyntheticCompute::new(Duration::from_micros(300)));
    let elems = compute.image_elems;
    let pool = StatePool::new(
        n_ues,
        StateNorm {
            lambda_tasks: tasks as f64,
            frame_s: 0.5,
            max_bits: 1e6,
            d_max: 100.0,
        },
    );
    let decisions = DecisionMaker::new(Box::new(StaticDecision::new(vec![
        HybridAction::new(0, 0, 0.0, 1.0);
        n_ues
    ])));
    let mut cfg = ServerConfig::new(n_ues, Duration::from_millis(20), usize::MAX);
    cfg.exec.workers = 2;

    let transport = TcpServerTransport::bind(("127.0.0.1", port), n_ues)?;
    let addr = transport.local_addr();
    println!("=== remote serving: edge server on {addr}, {n_ues} UEs x {tasks} tasks ===");
    let compute = Some(compute as Arc<dyn OffloadCompute>);
    let server = EdgeServer::spawn_on(cfg, pool, decisions, compute, transport)?;

    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_ues)
        .map(|ue| {
            let builder = std::thread::Builder::new().name(format!("ue-{ue}"));
            builder.spawn(move || -> Result<(u64, f64)> {
                // in a real deployment this block runs on another machine
                let mut client = UeClient::new(TcpClientTransport::connect(addr, ue)?);
                client.report(UeStateReport {
                    ue_id: ue,
                    tasks_left: tasks,
                    compute_left_s: 0.0,
                    offload_left_bits: 0.0,
                    distance_m: 40.0,
                })?;
                let d = client.await_decision(Duration::from_secs(15))?;
                if ue == 0 {
                    println!(
                        "UE 0: decision for frame {} covers {} UEs",
                        d.frame,
                        d.actions.len()
                    );
                    // show the NACK path: feature offloads need calibration
                    let demo_task = 424_242u64;
                    client.offload(demo_task, 2, vec![1u8; 8], None)?;
                    let err = client
                        .await_result(demo_task, Duration::from_secs(15))
                        .expect_err("the server must NACK a calibration-less feature offload");
                    println!("UE 0: NACK demo -> {err:#}");
                }
                let mut rtt = 0.0f64;
                for task in 0..tasks {
                    let payload = vec![(task % 251) as u8 + 1; 4 * elems];
                    let sent = Instant::now();
                    client.offload(task, 0, payload, None)?;
                    let res = client.await_result(task, Duration::from_secs(15))?;
                    rtt += sent.elapsed().as_secs_f64();
                    assert_eq!(res.task_id, task);
                }
                client.goodbye()?;
                Ok((tasks, rtt))
            })
        })
        .collect::<std::io::Result<Vec<_>>>()?;

    let mut total = 0u64;
    let mut rtt = 0.0f64;
    for h in handles {
        let (done, r) = h.join().expect("ue thread")?;
        total += done;
        rtt += r;
    }
    let stats = server.join();
    let wall = t0.elapsed().as_secs_f64();

    let rate = total as f64 / wall;
    println!("served {total} offloads in {wall:.2}s -> {rate:.1} req/s over TCP");
    let mean_rtt_ms = rtt / total as f64 * 1e3;
    println!("mean round-trip (socket + queue + compute): {mean_rtt_ms:.2} ms");
    println!(
        "ServerStats: {} frames | {} reports | {} served ({} raw)",
        stats.frames, stats.reports, stats.offloads_served, stats.raw_offloads
    );
    println!("offload errors: {} (1 = the NACK demo)", stats.offload_errors);
    println!(
        "executor: peak queue {} | mean queue wait {:.2} ms | {} batches",
        stats.exec.max_queue_depth, stats.exec.mean_queue_wait_s() * 1e3, stats.exec.batches
    );
    assert_eq!(stats.offloads_served as u64, total, "all offloads must complete");
    Ok(())
}
