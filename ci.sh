#!/usr/bin/env bash
# CI: tier-1 (build + test) plus hygiene and the perf baseline.
# Fully offline — every dependency is an in-tree path crate.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: test =="
cargo test -q

echo "== kernel tests, forced-scalar dispatch =="
# MACCI_FORCE_SCALAR is latched once per process, so rerun the kernel
# suites in fresh processes with SIMD off: the scalar fallback must pass
# the same goldens/properties the dispatched paths do
MACCI_FORCE_SCALAR=1 cargo test -q --lib runtime::native
MACCI_FORCE_SCALAR=1 cargo test -q --test proptests kernel_

echo "== zero-alloc data plane (counting global allocator) =="
# the steady-state serving paths must never touch the allocator
# (DESIGN.md §Data-Plane); runs as its own step/process because the
# counting #[global_allocator] must own the whole binary
cargo test -q --test zero_alloc

echo "== lint (repo invariants) =="
# self-test the rule engine first, then sweep the tree; any unsuppressed
# finding exits 1 and fails CI. Machine-readable report lands in LINT.json.
cargo test -p macci-lint -q
cargo run -p macci-lint -- --json LINT.json

echo "== rustfmt =="
cargo fmt --check

echo "== clippy =="
cargo clippy --all-targets -- -D warnings

echo "== rustdoc (warning-free) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p macci -q

echo "== PJRT path compile-check (xla stub) =="
cargo build --release --features xla-pjrt

echo "== quickstart (native backend, end-to-end) =="
cargo run --release --example quickstart

echo "== perf baseline (BENCH_runtime.json) =="
MACCI_BENCH_MS=${MACCI_BENCH_MS:-200} cargo bench --bench bench_runtime
MACCI_BENCH_MS=${MACCI_BENCH_MS:-200} cargo bench --bench bench_e2e

echo "== serving baseline (BENCH_serving.json) =="
MACCI_BENCH_SERVING_TASKS=${MACCI_BENCH_SERVING_TASKS:-48} cargo bench --bench bench_serving

echo "== fleet-load smoke (BENCH_load.json, bounded) =="
# short cells and a capped fleet keep this a smoke test in CI; unset the
# caps for the full 10k-UE sweep (README §Load harness)
MACCI_BENCH_MS=${MACCI_BENCH_MS:-200} \
MACCI_BENCH_LOAD_UES=${MACCI_BENCH_LOAD_UES:-2000} cargo bench --bench bench_load

echo "== wire-codec baseline (BENCH_wire.json) =="
MACCI_BENCH_MS=${MACCI_BENCH_MS:-200} cargo bench --bench bench_wire

echo "== training baseline: rollout + sharded update engine (BENCH_train.json) =="
MACCI_BENCH_MS=${MACCI_BENCH_MS:-200} cargo bench --bench bench_train

echo "== checkpoint + hot-swap baseline (BENCH_checkpoint.json) =="
MACCI_BENCH_MS=${MACCI_BENCH_MS:-200} cargo bench --bench bench_checkpoint

echo "== remote serving (loopback TCP, end-to-end) =="
cargo run --release --example remote_serving -- 2 8

echo "== policy lifecycle (train -> save -> resume -> serve -> online swap) =="
cargo run --release --example policy_lifecycle -- 512 300

echo "CI OK"
