"""Pallas quantize / dequantize kernels — the paper's Eq. (1) and Eq. (2).

Elementwise fixed-point mapping of the encoder output to `bits`-wide integer
codes (kept in f32 storage; the wire format is produced by the Rust side,
which packs the codes — the *information content* is what matters for the
compression-rate accounting, Eq. (3)).

On TPU these are VPU elementwise ops fused into the same HBM pass as the
encoder matmul epilogue; here each kernel is a single flat grid over tiles
of the flattened feature. min/max are passed in as scalars (the paper's
"pre-collected set of feature maps" calibration), so the kernel is a pure
map with no global reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TILE = 1024


def _pick_tile(n: int) -> int:
    for t in (_TILE, 512, 256, 128, 64, 32, 16, 8, 4, 2):
        if n % t == 0 and t <= n:
            return t
    return n


def _quant_kernel(x_ref, lo_ref, hi_ref, o_ref, *, bits: int):
    lo = lo_ref[0]
    hi = hi_ref[0]
    levels = jnp.float32(2**bits - 1)
    span = jnp.maximum(hi - lo, 1e-12)
    x = jnp.clip(x_ref[...], lo, hi)
    o_ref[...] = jnp.round(levels * (x - lo) / span)


def _dequant_kernel(y_ref, lo_ref, hi_ref, o_ref, *, bits: int):
    lo = lo_ref[0]
    hi = hi_ref[0]
    levels = jnp.float32(2**bits - 1)
    o_ref[...] = y_ref[...] * (hi - lo) / levels + lo


def _elementwise(kern, x: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, bits: int) -> jnp.ndarray:
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    t = _pick_tile(n)
    out = pl.pallas_call(
        functools.partial(kern, bits=bits),
        grid=(n // t,),
        in_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(flat, lo.reshape(1), hi.reshape(1))
    return out.reshape(shape)


def quantize(x: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Eq. (1): round((2^bits - 1) * (clip(x) - lo) / (hi - lo))."""
    return _elementwise(_quant_kernel, x, lo, hi, bits)


def dequantize(y: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Eq. (2): y * (hi - lo) / (2^bits - 1) + lo."""
    return _elementwise(_dequant_kernel, y, lo, hi, bits)


def quantize_ste(x: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize -> dequantize with a straight-through estimator.

    Used inside build-time autoencoder training so the round-off error is
    part of the loss (Eq. 4) while gradients flow as identity through the
    non-differentiable round(). The Pallas kernels run on a fully detached
    copy of `x` (interpret-mode pallas_call has no JVP rule), and the STE
    re-attaches the residual so d out / d x == identity.
    """
    xd = jax.lax.stop_gradient(x)
    lo = jax.lax.stop_gradient(lo)
    hi = jax.lax.stop_gradient(hi)
    q = dequantize(quantize(xd, lo, hi, bits), lo, hi, bits)
    return x + jax.lax.stop_gradient(q - xd)
