"""Pallas kernel for the paper's Sec. 2.2 channel-reduction autoencoder.

A 1x1 convolution over an (N, C, H, W) feature map is exactly a channel-mix
matmul over the flattened spatial axis: (N*H*W, C) @ (C, C'). On TPU that is
a pure MXU workload; the paper implemented it as a CUDA conv on a Jetson
Nano, we re-think it as a matmul (DESIGN.md §Hardware-Adaptation):

  * the full (C, C') weight lives in VMEM across the whole grid (worst case
    512x512 fp32 = 1 MiB << 16 MiB VMEM);
  * the spatial axis is tiled into blocks of `_TILE_S` rows so each grid
    step streams one HBM tile in, runs one MXU matmul, streams one tile out
    — the BlockSpec below *is* the HBM<->VMEM schedule the paper expressed
    with CUDA threadblocks.

custom_vjp makes the kernel differentiable so the build-time autoencoder
training (Eq. 4) backprops through it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TILE_S = 256


def _pick_tile(s: int) -> int:
    for t in (_TILE_S, 128, 64, 32, 16, 8, 4, 2):
        if s % t == 0 and t <= s:
            return t
    return s


def _mix_kernel(x_ref, w_ref, b_ref, o_ref):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc + b_ref[...][None, :]


def _channel_mix(xf: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(S, C) @ (C, C') + b with the S axis tiled."""
    s, c = xf.shape
    c2 = w.shape[1]
    ts = _pick_tile(s)
    return pl.pallas_call(
        _mix_kernel,
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((ts, c), lambda i: (i, 0)),
            pl.BlockSpec((c, c2), lambda i: (0, 0)),
            pl.BlockSpec((c2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ts, c2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, c2), jnp.float32),
        interpret=True,
    )(xf, w, b)


def _mm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    s = a.shape[0]
    ts = _pick_tile(s)
    kern = lambda a_ref, b_ref, o_ref: o_ref.__setitem__(
        ..., jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    )
    return pl.pallas_call(
        kern,
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((ts, a.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec(b.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ts, b.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, b.shape[1]), jnp.float32),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def conv1x1(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """1x1 conv: x (N, C, H, W), w (C, C'), b (C',) -> (N, C', H, W)."""
    n, c, h, wd = x.shape
    xf = x.transpose(0, 2, 3, 1).reshape(-1, c)
    yf = _channel_mix(xf, w, b)
    return yf.reshape(n, h, wd, w.shape[1]).transpose(0, 3, 1, 2)


def _conv1x1_fwd(x, w, b):
    return conv1x1(x, w, b), (x, w)


def _conv1x1_bwd(res, g):
    x, w = res
    n, c, h, wd = x.shape
    c2 = w.shape[1]
    gf = g.transpose(0, 2, 3, 1).reshape(-1, c2)   # (S, C')
    xf = x.transpose(0, 2, 3, 1).reshape(-1, c)    # (S, C)
    dxf = _mm(gf, w.T)                             # (S, C)
    dw = _mm(xf.T, gf) if xf.shape[1] % 2 == 0 else xf.T @ gf
    db = jnp.sum(gf, axis=0)
    dx = dxf.reshape(n, h, wd, c).transpose(0, 3, 1, 2)
    return dx, dw, db


conv1x1.defvjp(_conv1x1_fwd, _conv1x1_bwd)
