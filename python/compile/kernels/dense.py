"""Fused dense (matmul + bias + activation) Pallas kernel with custom VJP.

This is the L1 hot-spot of the MAHPPO actor/critic MLPs: every layer of
every network artifact lowers through this kernel, so it appears in both the
serving-path actor forward HLO and the training-path update HLO.

TPU mapping (see DESIGN.md §Hardware-Adaptation): one grid step per row-tile
of the batch; the full (IN, OUT) weight stays resident in VMEM (the largest
layer here is 256x128 fp32 = 128 KiB, far below the ~16 MiB VMEM budget), so
each step is a single MXU matmul with the bias-add + activation fused into
the epilogue on the VPU. The backward pass is two more MXU matmuls
(dX = g @ W^T, dW = X^T @ g) expressed as Pallas kernels as well, wired up
through jax.custom_vjp so jax.grad of the PPO losses differentiates through
the kernels.

All kernels run with interpret=True: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime executes directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Row-tile size for the batch axis. 128 matches the MXU systolic dimension;
# smaller batches fall back to a single tile.
_TILE_B = 128


def _tile(b: int) -> int:
    return _TILE_B if b % _TILE_B == 0 else b


def _dense_fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One row-tile: o = act(x @ w + b). Bias/activation fused in epilogue."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    o_ref[...] = ref.apply_activation(acc, activation)


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _pallas_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(M, K) @ (K, N) with the M axis tiled into VMEM-sized blocks."""
    m, k = a.shape
    n = b.shape[1]
    tm = _tile(m)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def _dense_forward(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str) -> jnp.ndarray:
    bsz, cin = x.shape
    cout = w.shape[1]
    tb = _tile(bsz)
    kern = functools.partial(_dense_fwd_kernel, activation=activation)
    return pl.pallas_call(
        kern,
        grid=(bsz // tb,),
        in_specs=[
            pl.BlockSpec((tb, cin), lambda i: (i, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, cout), jnp.float32),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str = "linear") -> jnp.ndarray:
    """Fused act(x @ w + b); differentiable via Pallas backward kernels."""
    return _dense_forward(x, w, b, activation)


def _dense_vjp_fwd(x, w, b, activation):
    y = _dense_forward(x, w, b, activation)
    return y, (x, w, y)


def _dense_vjp_bwd(activation, res, g):
    x, w, y = res
    if activation == "tanh":
        g = g * (1.0 - y * y)
    elif activation == "relu":
        g = g * (y > 0.0).astype(g.dtype)
    # dX = g @ W^T and dW = X^T @ g: two MXU matmuls.
    dx = _pallas_matmul(g, w.T)
    dw = _pallas_matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_vjp_fwd, _dense_vjp_bwd)
