"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (python/tests/test_kernels.py)
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these references to tight tolerances.
"""
from __future__ import annotations

import jax.numpy as jnp


def apply_activation(y: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "linear":
        return y
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    raise ValueError(f"unknown activation: {activation}")


def dense_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str = "linear") -> jnp.ndarray:
    """y = act(x @ w + b).  x: (B, IN), w: (IN, OUT), b: (OUT,)."""
    return apply_activation(x @ w + b, activation)


def conv1x1_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """1x1 convolution == per-pixel channel mix.

    x: (N, C, H, W), w: (C, C'), b: (C',) -> (N, C', H, W).

    This is the paper's Sec. 2.2 channel-reduction encoder/decoder: a conv
    layer with kernel (C, C', 1, 1) that shrinks/restores the channel axis.
    """
    n, c, h, wd = x.shape
    xf = x.transpose(0, 2, 3, 1).reshape(-1, c)  # (N*H*W, C)
    yf = xf @ w + b
    return yf.reshape(n, h, wd, w.shape[1]).transpose(0, 3, 1, 2)


def quantize_ref(x: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Paper Eq. (1): y_i = round((2^cq - 1) (x_i - min) / (max - min)).

    `lo`/`hi` are the calibration min/max (scalars); values outside are
    clipped into range, matching what a fixed-point transmitter must do.
    """
    levels = jnp.float32(2**bits - 1)
    span = jnp.maximum(hi - lo, 1e-12)
    return jnp.round(levels * (jnp.clip(x, lo, hi) - lo) / span)


def dequantize_ref(y: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Paper Eq. (2): x'_i = y_i (max - min) / (2^cq - 1) + min."""
    levels = jnp.float32(2**bits - 1)
    return y * (hi - lo) / levels + lo
