"""Synthetic stand-in for Caltech-101 (see DESIGN.md §Substitutions).

16-class, 32x32 RGB image classification. Each class owns a fixed low-
frequency template (an upsampled 4x4 random field plus a class-specific
oriented grating); samples are template + per-sample brightness/contrast
jitter + pixel noise + a random translation. The task is easy enough for a
few CPU epochs to reach high accuracy, yet the intermediate features retain
the channel redundancy the paper's compressor exploits — which is what the
compression-rate/accuracy trade-off experiments (Figs. 4/5/13ab) need.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

NUM_CLASSES = 16
IMG = 32


def _templates(rng: np.random.Generator) -> np.ndarray:
    """(K, 3, IMG, IMG) class templates."""
    tpl = np.empty((NUM_CLASSES, 3, IMG, IMG), np.float32)
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32) / IMG
    for k in range(NUM_CLASSES):
        low = rng.normal(0, 1, (3, 4, 4)).astype(np.float32)
        up = low.repeat(IMG // 4, axis=1).repeat(IMG // 4, axis=2)
        theta = np.pi * k / NUM_CLASSES
        freq = 3.0 + (k % 4)
        grating = np.sin(2 * np.pi * freq * (np.cos(theta) * xx + np.sin(theta) * yy))
        tpl[k] = 0.7 * up + 0.6 * grating[None]
    return tpl


def make_dataset(
    n_train: int = 1024, n_test: int = 256, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (x_train, y_train, x_test, y_test); images NCHW float32."""
    rng = np.random.default_rng(seed)
    tpl = _templates(rng)

    def gen(n: int) -> Tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, NUM_CLASSES, n).astype(np.int32)
        x = tpl[y].copy()
        # brightness / contrast jitter
        x *= rng.uniform(0.8, 1.2, (n, 1, 1, 1)).astype(np.float32)
        x += rng.uniform(-0.2, 0.2, (n, 1, 1, 1)).astype(np.float32)
        # random translation up to +-3 px
        for i in range(n):
            dx, dy = rng.integers(-3, 4, 2)
            x[i] = np.roll(x[i], (dy, dx), axis=(1, 2))
        x += rng.normal(0, 0.25, x.shape).astype(np.float32)
        return x.astype(np.float32), y

    xtr, ytr = gen(n_train)
    xte, yte = gen(n_test)
    return xtr, ytr, xte, yte
