"""MAHPPO actor / critic networks and PPO-clip update steps (paper Sec. 5).

Architecture (paper Sec. 6.3.1 "Agent"):
  * each of the N actors: shared trunk FC 4N->256->128 (tanh), then three
    branch heads (64 hidden each):
      - partition-point branch -> B_n+2 logits -> softmax        (Eq. 13)
      - offloading-channel branch -> C logits -> softmax          (Eq. 13)
      - transmit-power branch -> (mu, log_std) of a Gaussian      (Eq. 14)
  * one central critic: FC 4N->256->128->64->1.

Every layer routes through the Pallas `dense` kernel, so both the B=1
serving forward and the fwd+bwd+Adam update artifacts carry the L1 kernels
in their HLO.

The *hybrid* action log-prob (used for the PPO ratio, Eq. 17/19) is the sum
of the two categorical log-probs and the Gaussian log-prob — the three
branches are conditionally independent given the state.

Action semantics: the continuous head emits an unsquashed pre-action `a_p`;
the environment maps it to power via p = p_max * sigmoid(a_p), which keeps
the policy-gradient math exactly Gaussian while enforcing constraint (C3)
0 < p <= p_max.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import (
    ParamSpec,
    adam_step,
    categorical_entropy,
    gaussian_entropy,
    gaussian_log_prob,
)
from .kernels.dense import dense

# Network size constants (paper Sec. 6.3.1).
TRUNK = (256, 128)
BRANCH_HIDDEN = 64
CRITIC = (256, 128, 64)


@dataclass(frozen=True)
class ActorConfig:
    n_ues: int          # N — state is 4 vectors of length N
    n_partition: int    # B_n + 2 discrete split choices (0..B_n+1)
    n_channels: int     # C

    @property
    def state_dim(self) -> int:
        return 4 * self.n_ues


def actor_spec(cfg: ActorConfig) -> ParamSpec:
    d = cfg.state_dim
    return ParamSpec.build(
        [
            ("w_t0", (d, TRUNK[0])),
            ("b_t0", (TRUNK[0],)),
            ("w_t1", (TRUNK[0], TRUNK[1])),
            ("b_t1", (TRUNK[1],)),
            # partition-point branch
            ("w_b0", (TRUNK[1], BRANCH_HIDDEN)),
            ("b_b0", (BRANCH_HIDDEN,)),
            ("w_b1", (BRANCH_HIDDEN, cfg.n_partition)),
            ("b_b1", (cfg.n_partition,)),
            # channel branch
            ("w_c0", (TRUNK[1], BRANCH_HIDDEN)),
            ("b_c0", (BRANCH_HIDDEN,)),
            ("w_c1", (BRANCH_HIDDEN, cfg.n_channels)),
            ("b_c1", (cfg.n_channels,)),
            # power branch: mu and a state-dependent log_std
            ("w_p0", (TRUNK[1], BRANCH_HIDDEN)),
            ("b_p0", (BRANCH_HIDDEN,)),
            ("w_p1", (BRANCH_HIDDEN, 2)),
            ("b_p1_mu", (1,)),
            ("b_p1_log_std", (1,)),
        ]
    )


def critic_spec(cfg: ActorConfig) -> ParamSpec:
    d = cfg.state_dim
    return ParamSpec.build(
        [
            ("w_0", (d, CRITIC[0])),
            ("b_0", (CRITIC[0],)),
            ("w_1", (CRITIC[0], CRITIC[1])),
            ("b_1", (CRITIC[1],)),
            ("w_2", (CRITIC[1], CRITIC[2])),
            ("b_2", (CRITIC[2],)),
            ("w_3", (CRITIC[2], 1)),
            ("b_3", (1,)),
        ]
    )


def _softmax(logits: jnp.ndarray) -> jnp.ndarray:
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def actor_forward(
    cfg: ActorConfig, flat: jnp.ndarray, state: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """state (B, 4N) -> (probs_b (B,P), probs_c (B,C), mu (B,1), log_std (B,1))."""
    p = actor_spec(cfg).unflatten(flat)
    h = dense(state, p["w_t0"], p["b_t0"], "tanh")
    h = dense(h, p["w_t1"], p["b_t1"], "tanh")

    hb = dense(h, p["w_b0"], p["b_b0"], "tanh")
    logits_b = dense(hb, p["w_b1"], p["b_b1"], "linear")

    hc = dense(h, p["w_c0"], p["b_c0"], "tanh")
    logits_c = dense(hc, p["w_c1"], p["b_c1"], "linear")

    hp = dense(h, p["w_p0"], p["b_p0"], "tanh")
    bias_p = jnp.concatenate([p["b_p1_mu"], p["b_p1_log_std"]])
    mu_std = dense(hp, p["w_p1"], bias_p, "linear")
    mu = mu_std[:, 0:1]
    log_std = jnp.clip(mu_std[:, 1:2], -4.0, 1.0)

    return _softmax(logits_b), _softmax(logits_c), mu, log_std


def critic_forward(cfg: ActorConfig, flat: jnp.ndarray, state: jnp.ndarray) -> jnp.ndarray:
    """state (B, 4N) -> value (B, 1)."""
    p = critic_spec(cfg).unflatten(flat)
    h = dense(state, p["w_0"], p["b_0"], "tanh")
    h = dense(h, p["w_1"], p["b_1"], "tanh")
    h = dense(h, p["w_2"], p["b_2"], "tanh")
    return dense(h, p["w_3"], p["b_3"], "linear")


def hybrid_log_prob(
    cfg: ActorConfig,
    flat: jnp.ndarray,
    state: jnp.ndarray,
    a_b: jnp.ndarray,      # (B,) int32 partition choice
    a_c: jnp.ndarray,      # (B,) int32 channel choice
    a_p: jnp.ndarray,      # (B,) f32 pre-squash power action
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-sample hybrid log pi(a|s) and entropy H(pi(.|s))."""
    probs_b, probs_c, mu, log_std = actor_forward(cfg, flat, state)
    bsz = state.shape[0]
    idx = jnp.arange(bsz)
    lp_b = jnp.log(jnp.clip(probs_b[idx, a_b], 1e-8, 1.0))
    lp_c = jnp.log(jnp.clip(probs_c[idx, a_c], 1e-8, 1.0))
    lp_p = gaussian_log_prob(a_p, mu[:, 0], log_std[:, 0])
    logp = lp_b + lp_c + lp_p
    ent = (
        categorical_entropy(probs_b)
        + categorical_entropy(probs_c)
        + gaussian_entropy(log_std[:, 0])
    )
    return logp, ent


def actor_loss(
    cfg: ActorConfig,
    flat: jnp.ndarray,
    state: jnp.ndarray,
    a_b: jnp.ndarray,
    a_c: jnp.ndarray,
    a_p: jnp.ndarray,
    old_logp: jnp.ndarray,
    adv: jnp.ndarray,
    clip_eps: float,
    entropy_coef: float,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Negative of Eq. (20)'s per-actor term: -(L_CLIP + zeta * H)."""
    logp, ent = hybrid_log_prob(cfg, flat, state, a_b, a_c, a_p)
    ratio = jnp.exp(logp - old_logp)
    surr1 = ratio * adv
    surr2 = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    l_clip = jnp.mean(jnp.minimum(surr1, surr2))          # Eq. (19)
    entropy = jnp.mean(ent)
    loss = -(l_clip + entropy_coef * entropy)
    clip_frac = jnp.mean((jnp.abs(ratio - 1.0) > clip_eps).astype(jnp.float32))
    return loss, (entropy, clip_frac)


def actor_update(
    cfg: ActorConfig,
    flat: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    t: jnp.ndarray,          # scalar f32, 1-based Adam step
    lr: jnp.ndarray,         # scalar f32
    state: jnp.ndarray,
    a_b: jnp.ndarray,
    a_c: jnp.ndarray,
    a_p: jnp.ndarray,
    old_logp: jnp.ndarray,
    adv: jnp.ndarray,
    clip_eps: float = 0.2,
    entropy_coef: float = 0.001,
):
    """One PPO minibatch step for one actor. Returns the full tuple the Rust
    trainer needs: (params', m', v', loss, entropy, clip_frac)."""
    (loss, (ent, cf)), g = jax.value_and_grad(
        lambda f: actor_loss(cfg, f, state, a_b, a_c, a_p, old_logp, adv, clip_eps, entropy_coef),
        has_aux=True,
    )(flat)
    p2, m2, v2 = adam_step(flat, g, m, v, t, lr)
    return p2, m2, v2, loss, ent, cf


def critic_update(
    cfg: ActorConfig,
    flat: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    t: jnp.ndarray,
    lr: jnp.ndarray,
    state: jnp.ndarray,
    returns: jnp.ndarray,    # (B,) sampled cumulative reward V' (Eq. 15)
):
    """One critic minibatch step minimizing Eq. (16) (MSE to V')."""

    def loss_fn(f):
        v_pred = critic_forward(cfg, f, state)[:, 0]
        return jnp.mean((v_pred - returns) ** 2)

    loss, g = jax.value_and_grad(loss_fn)(flat)
    p2, m2, v2 = adam_step(flat, g, m, v, t, lr)
    return p2, m2, v2, loss
