"""Device overhead model — the substitute for the paper's Jetson Nano +
high-voltage power monitor testbed (Fig. 6; DESIGN.md §Substitutions).

The MDP (Sec. 3.4) consumes, per UE model and partition decision b:
  t_f(b)  local inference latency        e_f(b)  local inference energy
  t_c(b)  feature compression latency    e_c(b)  compression energy
  f(b)    offloaded payload size in bits

The paper measures these on hardware; we compute them analytically from the
REAL architectures' per-module FLOPs (backbones/*.py `module_stats`, paper
scale: 224x224 input, full width) through a calibrated Jetson-Nano-class
device model:

  latency(module)  = flops / (peak * util(kind)) + dispatch_overhead
  power(module)    = p_idle_active + p_dyn * util(kind)
  energy(module)   = latency * power

`util` is the achievable fraction of peak for the module kind: wide convs
keep the GPU busy (high util -> high power, low latency), depthwise convs
and FC layers underutilize it. This reproduces the paper's Fig. 7 topology,
including its counter-intuitive finding that running only the first 4 stages
can cost MORE energy than the whole network (high-parallelism conv prefix
draws more average power than the tail).

Calibration anchors (paper Sec. 6.3.1): full-local ResNet18 latency ~50 ms
(T0 = 0.5 s is "about 10x larger"), beta = 0.47 = latency/energy ratio =>
full-local energy ~107 mJ at ~2.1 W of active inference power on the 5 W
Jetson power mode.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, asdict
from typing import Dict, List, Optional

from .backbones import build
from .autoencoder import AeConfig

# ------------------------------------------------------------------ device
@dataclass(frozen=True)
class DeviceModel:
    """Jetson-Nano-class UE in 5 W mode, DVFS off."""

    peak_flops: float = 118e9      # fp32-equivalent sustained peak, 5 W mode
    util_conv: float = 0.75        # wide convolutions: near-full occupancy
    util_dwconv: float = 0.25      # depthwise: memory-bound
    util_fc: float = 0.30          # small GEMV tails
    util_pool: float = 0.15
    util_ae: float = 0.60          # 1x1 conv channel mix (matmul-shaped)
    dispatch_s: float = 120e-6     # per-module kernel launch + sync
    p_active_base: float = 0.9     # W above idle when any kernel runs
    p_dyn: float = 2.0             # W * util on top of base
    # JALAD-style entropy coding runs on the CPU cores:
    cpu_code_bps: float = 30e6 * 8  # bits/s through the Huffman coder
    cpu_power: float = 1.4          # W while entropy coding

    def util(self, kind: str) -> float:
        return {
            "conv": self.util_conv,
            "dwconv": self.util_dwconv,
            "fc": self.util_fc,
            "pool": self.util_pool,
            "ae": self.util_ae,
        }[kind]

    def module_cost(self, flops: float, kind: str) -> Dict[str, float]:
        u = self.util(kind)
        lat = flops / (self.peak_flops * u) + self.dispatch_s
        power = self.p_active_base + self.p_dyn * u
        return {"latency": lat, "power": power, "energy": lat * power}


# --------------------------------------------------------------- profiles
INPUT_BITS = 224 * 224 * 3 * 8  # raw 8-bit RGB frame offloaded when b = 0

# Channel-reduction factors per partition point for the *paper-geometry*
# simulation profile: the paper's Fig. 4 shows the AE's achievable rate
# DECREASING with depth (shallow features are the most channel-redundant),
# with overall rates R ~ up to >100x at point 1 down to ~16x at point 4.
# R_c = [32, 16, 8, 4] with 8-bit quantization gives R = [128, 64, 32, 16],
# matching that geometry. The demo-scale measured rates (trainer.py sweep on
# the synthetic task) are emitted as a separate `{model}_measured.json`
# profile; the synthetic task's features are less redundant than
# Caltech-101's, so its rates are conservative (see DESIGN.md
# §Substitutions).
PAPER_RC = [32, 16, 8, 4]


def build_profile(
    model: str,
    chosen_rates: Optional[List[Dict]] = None,
    device: Optional[DeviceModel] = None,
) -> Dict:
    """Per-partition-decision overhead table for one model at paper scale.

    `chosen_rates`: per point, {"ch_r": int, "bits": int} from the demo-scale
    compression sweep (trainer.py); if absent, R_c = 4 / 8-bit defaults are
    used. Returns the JSON-serializable profile the Rust side loads.
    """
    device = device or DeviceModel()
    bb = build(model, "paper")
    stats = bb.module_stats()
    points = bb.partition_points  # 4 cut indices
    n_choices = len(points) + 2   # b in {0, 1..4, 5}

    # cumulative local-inference latency/energy after each module
    cum = [{"latency": 0.0, "energy": 0.0}]
    for st in stats:
        kind = st.kind
        if model == "mobilenetv2" and kind == "conv" and "blk" in st.name:
            kind = "dwconv"  # inverted residuals are depthwise-dominated
        c = device.module_cost(st.flops, kind)
        cum.append(
            {
                "latency": cum[-1]["latency"] + c["latency"],
                "energy": cum[-1]["energy"] + c["energy"],
            }
        )

    full = cum[-1]
    entries = []
    for b in range(n_choices):
        if b == 0:  # offload raw input
            entries.append(
                {
                    "b": 0,
                    "t_f": 0.0,
                    "e_f": 0.0,
                    "t_c": 0.0,
                    "e_c": 0.0,
                    "bits": float(INPUT_BITS),
                }
            )
        elif b == n_choices - 1:  # full local
            entries.append(
                {
                    "b": b,
                    "t_f": full["latency"],
                    "e_f": full["energy"],
                    "t_c": 0.0,
                    "e_c": 0.0,
                    "bits": 0.0,
                }
            )
        else:  # split at point b
            cut = points[b - 1]
            ch, h, w = bb.feature_shape(b)
            if chosen_rates is not None:
                sel = chosen_rates[b - 1]
                cfg = AeConfig(ch=ch, ch_r=sel["ch_r_paper"], bits=sel.get("bits", 8))
            else:
                cfg = AeConfig(ch=ch, ch_r=max(1, ch // PAPER_RC[b - 1]), bits=8)
            # AE encoder cost: 1x1 conv ch->ch' over h*w + quantization pass
            enc_flops = 2.0 * ch * cfg.ch_r * h * w + 4.0 * cfg.ch_r * h * w
            c = device.module_cost(enc_flops, "ae")
            entries.append(
                {
                    "b": b,
                    "t_f": cum[cut]["latency"],
                    "e_f": cum[cut]["energy"],
                    "t_c": c["latency"],
                    "e_c": c["energy"],
                    "bits": cfg.compressed_bits(h, w),
                    "feature": {"ch": ch, "ch_r": cfg.ch_r, "h": h, "w": w, "rate": cfg.rate},
                }
            )

    # JALAD baseline: 8-bit quant + entropy coding of the RAW feature map.
    jalad = []
    for b in range(1, n_choices - 1):
        ch, h, w = bb.feature_shape(b)
        raw_bits = ch * h * w * 8.0
        # entropy coding achieves ~2.2x on 8-bit quantized conv features
        # (JALAD reports ~18x vs fp32 == ~4.5x over the 8-bit codes early,
        # improving with depth as features sparsify — modeled linearly).
        ec_gain = 1.6 + 0.5 * b
        code_lat = raw_bits / device.cpu_code_bps
        jalad.append(
            {
                "b": b,
                "t_c": code_lat,
                "e_c": code_lat * device.cpu_power,
                "bits": raw_bits / ec_gain,
                "rate": 32.0 / 8.0 * ec_gain,
            }
        )

    return {
        "model": model,
        "scale": "paper",
        "input_bits": float(INPUT_BITS),
        "full_local": {"t": full["latency"], "e": full["energy"]},
        "n_partition_choices": n_choices,
        "entries": entries,
        "jalad": jalad,
        "device": asdict(device),
        "modules": [
            {"name": s.name, "flops": s.flops, "kind": s.kind, "out": list(s.out_shape)}
            for s in stats
        ],
    }


def write_profiles(out_dir: str, compression_dir: Optional[str] = None, log=print) -> None:
    """Emit two profile variants per model:

    * `{model}.json` — paper-geometry compression rates (PAPER_RC); the
      default for the MDP experiments, reproducing the paper's regime.
    * `{model}_measured.json` — rates measured by the demo-scale sweep
      (only when compression summaries exist); used for the measured-rate
      ablation.
    """
    os.makedirs(out_dir, exist_ok=True)
    for model in ("resnet18", "vgg11", "mobilenetv2"):
        prof = build_profile(model, None)
        path = os.path.join(out_dir, f"{model}.json")
        with open(path, "w") as f:
            json.dump(prof, f, indent=1)
        log(
            f"[profile] {model}: full-local t={prof['full_local']['t']*1e3:.1f} ms "
            f"e={prof['full_local']['e']*1e3:.1f} mJ -> {path}"
        )
        if compression_dir:
            cpath = os.path.join(compression_dir, f"{model}.json")
            if os.path.exists(cpath):
                with open(cpath) as f:
                    summary = json.load(f)
                chosen = []
                bb = build(model, "paper")
                for p in summary["points"]:
                    # map the demo-scale chosen R_c onto paper-scale channels
                    rc = max(2.0, p["ch"] / p["chosen"]["ch_r"])
                    ch_paper = bb.feature_shape(p["point"])[0]
                    chosen.append({"ch_r_paper": max(1, int(round(ch_paper / rc))), "bits": 8})
                mprof = build_profile(model, chosen)
                mpath = os.path.join(out_dir, f"{model}_measured.json")
                with open(mpath, "w") as f:
                    json.dump(mprof, f, indent=1)
