"""Build-time training: backbone pretraining + autoencoder sweeps (Sec. 2.4).

Everything here runs ONCE under `make artifacts` and writes JSON summaries
consumed by the Rust experiment harness:

  artifacts/compression/{model}.json
    base accuracy, per-partition-point AE rate sweep (Fig. 4 / 13ab data),
    the selected max-rate-under-2%-loss configs the MDP profile uses, and
    the xi sweep (Fig. 5 data).

Two-stage optimization (paper Sec. 2.4): stage 1 trains the AE with the
frozen backbone minimizing  ||T_i - T_o||_2 + xi * d_ce(M(x), y)  (Eq. 4);
stage 2 (optional, `finetune_epochs > 0`) fine-tunes everything jointly at a
small learning rate. The CE term requires a forward through the frozen back
half each step, which dominates cost; the rate sweep therefore trains with
the pure reconstruction term (xi = 0) and *evaluates* task accuracy exactly,
while the dedicated xi-sweep (Fig. 5) trains with the full Eq. (4) on a
subset. DESIGN.md §Substitutions records this budget trade.
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import datasets
from .autoencoder import AeConfig, ae_init, reconstruct_ste
from .backbones import build
from .layers import Params, StatsTape, apply_stats_updates, softmax_cross_entropy


# ---------------------------------------------------------------- optimizer
def tree_adam_init(params):
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params), "t": jnp.float32(0)}


def tree_adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    def upd(p, m_, v_):
        mh = m_ / (1 - b1**t)
        vh = v_ / (1 - b2**t)
        return p - lr * mh / (jnp.sqrt(vh) + eps)
    return jax.tree_util.tree_map(upd, params, m, v), {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------- backbone
@dataclass
class TrainBudget:
    """Knobs sized for the single-core CPU build (override via env)."""

    n_train: int = int(os.environ.get("MACCI_N_TRAIN", 512))
    n_test: int = int(os.environ.get("MACCI_N_TEST", 256))
    pretrain_epochs: int = int(os.environ.get("MACCI_PRETRAIN_EPOCHS", 3))
    ae_epochs: int = int(os.environ.get("MACCI_AE_EPOCHS", 2))
    xi_epochs: int = int(os.environ.get("MACCI_XI_EPOCHS", 1))
    xi_subset: int = int(os.environ.get("MACCI_XI_SUBSET", 192))
    finetune_epochs: int = int(os.environ.get("MACCI_FINETUNE_EPOCHS", 0))
    batch: int = 32
    lr: float = 2e-3
    seed: int = 0


def pretrain_backbone(model: str, budget: TrainBudget, log=print):
    """Train the demo-scale backbone on the synthetic dataset."""
    bb = build(model, "demo", num_classes=datasets.NUM_CLASSES)
    xtr, ytr, xte, yte = datasets.make_dataset(budget.n_train, budget.n_test, budget.seed)
    params = bb.init(budget.seed)
    opt = tree_adam_init(params)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            tape = StatsTape()
            logits = bb.forward(p, x, train=True, tape=tape)
            return softmax_cross_entropy(logits, y), tape.updates
        (loss, updates), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = tree_adam_step(params, g, opt, budget.lr)
        tape = StatsTape()
        tape.updates = updates
        params = apply_stats_updates(params, tape)
        return params, opt, loss

    rng = np.random.default_rng(budget.seed)
    n = xtr.shape[0]
    for ep in range(budget.pretrain_epochs):
        order = rng.permutation(n)
        losses = []
        t0 = time.time()
        for i in range(0, n - budget.batch + 1, budget.batch):
            idx = order[i : i + budget.batch]
            params, opt, loss = step(params, jax.device_put(opt), jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))
            losses.append(float(loss))
        acc = evaluate(bb, params, xte, yte, budget.batch)
        log(f"  [{model}] epoch {ep}: loss={np.mean(losses):.3f} test_acc={acc:.3f} ({time.time()-t0:.1f}s)")
    return bb, params, (xtr, ytr, xte, yte)


def evaluate(bb, params, x, y, batch=64, ae=None):
    """Test accuracy; optionally with an (AeConfig, ae_params, point) compressor inserted."""
    correct = 0
    for i in range(0, x.shape[0], batch):
        xb = jnp.asarray(x[i : i + batch])
        if ae is None:
            logits = bb.forward(params, xb)
        else:
            cfg, ap, point = ae
            feat = bb.forward_front(params, xb, point)
            recon = reconstruct_ste(cfg, ap, feat)
            logits = bb.forward_back(params, recon, point)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(y[i : i + batch])))
    return correct / x.shape[0]


# ---------------------------------------------------------------- AE train
def train_ae(
    bb,
    params: Params,
    point: int,
    cfg: AeConfig,
    data,
    budget: TrainBudget,
    xi: float = 0.0,
    epochs: Optional[int] = None,
    subset: Optional[int] = None,
    log=print,
) -> Dict:
    """Stage-1 AE training (Eq. 4) with the backbone frozen."""
    xtr, ytr, _, _ = data
    if subset:
        xtr, ytr = xtr[:subset], ytr[:subset]
    epochs = epochs if epochs is not None else budget.ae_epochs
    ae_params = {k: jnp.asarray(v) for k, v in ae_init(cfg, budget.seed + point).items()}
    opt = tree_adam_init(ae_params)
    lr = 1e-2  # paper uses 0.1 with SGD; Adam at 1e-2 converges in few epochs

    # Precompute frozen features once per epoch batch loop (front is frozen).
    @jax.jit
    def front(xb):
        return bb.forward_front(params, xb, point)

    if xi > 0.0:
        @jax.jit
        def step(ae_p, opt, feat, xb_labels):
            def loss_fn(ap):
                recon = reconstruct_ste(cfg, ap, feat)
                l2 = jnp.sqrt(jnp.sum((feat - recon) ** 2) / feat.shape[0] + 1e-12)
                logits = bb.forward_back(params, recon, point)
                ce = softmax_cross_entropy(logits, xb_labels)
                return l2 + xi * ce
            loss, g = jax.value_and_grad(loss_fn)(ae_p)
            ae_p, opt = tree_adam_step(ae_p, g, opt, lr)
            return ae_p, opt, loss
    else:
        @jax.jit
        def step(ae_p, opt, feat, xb_labels):
            def loss_fn(ap):
                recon = reconstruct_ste(cfg, ap, feat)
                return jnp.sqrt(jnp.sum((feat - recon) ** 2) / feat.shape[0] + 1e-12)
            loss, g = jax.value_and_grad(loss_fn)(ae_p)
            ae_p, opt = tree_adam_step(ae_p, g, opt, lr)
            return ae_p, opt, loss

    rng = np.random.default_rng(budget.seed)
    n = xtr.shape[0]
    for ep in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - budget.batch + 1, budget.batch):
            idx = order[i : i + budget.batch]
            feat = front(jnp.asarray(xtr[idx]))
            ae_params, opt, loss = step(ae_params, opt, feat, jnp.asarray(ytr[idx]))
    return ae_params


# ------------------------------------------------------------- experiments
def rate_sweep_for_point(bb, params, data, point, budget, acc_base, log=print) -> Dict:
    """Fig. 4: find max compression rate with <= 2% accuracy loss."""
    ch, h, w = bb.feature_shape(point)
    sweep = []
    chosen = None
    for rc in (2, 4, 8, 16, 32):
        ch_r = max(1, ch // rc)
        if ch_r >= ch:
            continue
        cfg = AeConfig(ch=ch, ch_r=ch_r, bits=8)
        ae_params = train_ae(bb, params, point, cfg, data, budget, xi=0.0, log=log)
        acc = evaluate(bb, params, data[2], data[3], budget.batch, ae=(cfg, ae_params, point))
        entry = {
            "ch_r": ch_r,
            "rate": cfg.rate,
            "acc": acc,
            "acc_drop": acc_base - acc,
        }
        sweep.append(entry)
        log(f"    point {point}: ch {ch}->{ch_r} R={cfg.rate:.1f} acc={acc:.3f} (drop {acc_base-acc:+.3f})")
        if acc_base - acc <= 0.02:
            chosen = {**entry, "params": ae_params, "cfg": cfg}
        else:
            break  # higher rates will only be worse
    if chosen is None:  # even R_c=2 broke the bound: keep it anyway (documented)
        best = max(sweep, key=lambda e: e["acc"])
        cfg = AeConfig(ch=ch, ch_r=best["ch_r"], bits=8)
        chosen = {**best, "params": train_ae(bb, params, point, cfg, data, budget), "cfg": cfg}
    return {"ch": ch, "h": h, "w": w, "sweep": sweep, "chosen": chosen}


def xi_sweep(bb, params, data, budget, log=print) -> List[Dict]:
    """Fig. 5: accuracy per xi setting at each partition point (fixed R_c)."""
    out = []
    for point in range(1, 5):
        ch, _, _ = bb.feature_shape(point)
        cfg = AeConfig(ch=ch, ch_r=max(1, ch // 8), bits=8)
        for xi in (0.0, 0.01, 0.1, 1.0):
            ae_params = train_ae(
                bb, params, point, cfg, data, budget,
                xi=xi, epochs=budget.xi_epochs, subset=budget.xi_subset, log=log,
            )
            acc = evaluate(bb, params, data[2], data[3], budget.batch, ae=(cfg, ae_params, point))
            out.append({"point": point, "xi": xi, "acc": acc})
            log(f"    xi-sweep point {point} xi={xi}: acc={acc:.3f}")
    return out


def run_compression_experiments(model: str, out_dir: str, budget: Optional[TrainBudget] = None, with_xi: bool = False, log=print):
    """Full Sec. 6.1 pipeline for one model; returns summary + trained weights."""
    budget = budget or TrainBudget()
    log(f"[trainer] pretraining {model} (demo scale)")
    bb, params, data = pretrain_backbone(model, budget, log=log)
    acc_base = evaluate(bb, params, data[2], data[3], budget.batch)
    log(f"[trainer] {model} base accuracy: {acc_base:.3f}")

    points = []
    for point in range(1, 5):
        res = rate_sweep_for_point(bb, params, data, point, budget, acc_base, log=log)
        points.append(res)

    xi_results = xi_sweep(bb, params, data, budget, log=log) if with_xi else []

    summary = {
        "model": model,
        "base_acc": acc_base,
        "points": [
            {
                "point": i + 1,
                "ch": p["ch"],
                "h": p["h"],
                "w": p["w"],
                "sweep": [{k: e[k] for k in ("ch_r", "rate", "acc", "acc_drop")} for e in p["sweep"]],
                "chosen": {k: p["chosen"][k] for k in ("ch_r", "rate", "acc", "acc_drop")},
            }
            for i, p in enumerate(points)
        ],
        "xi_sweep": xi_results,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{model}.json"), "w") as f:
        json.dump(summary, f, indent=1)
    return bb, params, points, summary
