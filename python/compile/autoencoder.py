"""Lightweight autoencoder feature compressor (paper Sec. 2).

Encoder = one 1x1 conv shrinking channels ch -> ch' (compression rate
R_c = ch/ch'), decoder = one 1x1 conv restoring ch' -> ch, plus `bits`-wide
quantization of the encoder output (R_q = 32/bits). Overall rate, Eq. (3):

    R = R_c * R_q = ch * 32 / (ch' * bits)

Both convs route through the Pallas `conv1x1` kernel; quantization routes
through the Pallas `quant` kernels with a straight-through estimator during
training so the round-off error participates in the loss (Eq. 4).

Calibration: quant min/max are taken per-tensor at inference (the paper
permits replacing them with stats from a pre-collected set; per-tensor is
what the AOT encode artifact does, transmitting lo/hi alongside the codes —
2 floats of overhead, negligible against the feature payload).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.conv1x1 import conv1x1
from .kernels import quant as qk


@dataclass(frozen=True)
class AeConfig:
    ch: int        # channels of the intermediate feature at this cut
    ch_r: int      # reduced channels (ch' < ch)
    bits: int = 8  # quantization bit-width c_q

    @property
    def rate(self) -> float:
        """Overall compression rate R (Eq. 3)."""
        return self.ch * 32.0 / (self.ch_r * self.bits)

    def compressed_bits(self, h: int, w: int) -> float:
        """Wire size of one compressed feature map (bits)."""
        return self.ch_r * h * w * self.bits + 64.0  # + lo/hi floats


def ae_init(cfg: AeConfig, seed: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    we = rng.normal(0.0, math.sqrt(1.0 / cfg.ch), (cfg.ch, cfg.ch_r)).astype(np.float32)
    wd = rng.normal(0.0, math.sqrt(1.0 / cfg.ch_r), (cfg.ch_r, cfg.ch)).astype(np.float32)
    return {
        "w_enc": we,
        "b_enc": np.zeros(cfg.ch_r, np.float32),
        "w_dec": wd,
        "b_dec": np.zeros(cfg.ch, np.float32),
    }


def ae_flatten(params: Dict) -> np.ndarray:
    return np.concatenate(
        [np.asarray(params[k], np.float32).reshape(-1) for k in ("w_enc", "b_enc", "w_dec", "b_dec")]
    )


def ae_unflatten(cfg: AeConfig, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    c, cr = cfg.ch, cfg.ch_r
    o = 0
    out = {}
    for name, shape in (
        ("w_enc", (c, cr)),
        ("b_enc", (cr,)),
        ("w_dec", (cr, c)),
        ("b_dec", (c,)),
    ):
        n = int(np.prod(shape))
        out[name] = flat[o : o + n].reshape(shape)
        o += n
    return out


def encode(cfg: AeConfig, params: Dict, feat: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """UE side: channel-reduce + quantize. Returns (codes, lo, hi)."""
    z = conv1x1(feat, params["w_enc"], params["b_enc"])
    lo = jnp.min(z)
    hi = jnp.max(z)
    codes = qk.quantize(z, lo, hi, cfg.bits)
    return codes, lo, hi


def decode(cfg: AeConfig, params: Dict, codes: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Edge side: dequantize + channel-restore."""
    z = qk.dequantize(codes, lo, hi, cfg.bits)
    return conv1x1(z, params["w_dec"], params["b_dec"])


def reconstruct_ste(cfg: AeConfig, params: Dict, feat: jnp.ndarray) -> jnp.ndarray:
    """Training path: encode -> (quantize with STE) -> decode."""
    z = conv1x1(feat, params["w_enc"], params["b_enc"])
    lo = jax.lax.stop_gradient(jnp.min(z))
    hi = jax.lax.stop_gradient(jnp.max(z))
    zq = qk.quantize_ste(z, lo, hi, cfg.bits)
    return conv1x1(zq, params["w_dec"], params["b_dec"])
