"""Shared plumbing for the compile path.

Flat-parameter convention: every network artifact exchanged with the Rust
runtime takes its parameters as ONE flat f32 vector and unflattens it
internally. `ParamSpec` owns the (name -> shape) layout, the offsets, the
flatten/unflatten maps and the seeded initialization, and is serialized into
artifacts/manifest.json so the Rust side can size and checkpoint the vectors
without any pytree knowledge.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamSpec:
    """Ordered (name, shape) layout of a network's parameters."""

    entries: Tuple[Tuple[str, Tuple[int, ...]], ...]

    @staticmethod
    def build(entries: Sequence[Tuple[str, Sequence[int]]]) -> "ParamSpec":
        return ParamSpec(tuple((n, tuple(s)) for n, s in entries))

    @property
    def size(self) -> int:
        return sum(int(np.prod(s)) for _, s in self.entries)

    def offsets(self) -> List[Tuple[str, int, int, Tuple[int, ...]]]:
        out, off = [], 0
        for name, shape in self.entries:
            n = int(np.prod(shape))
            out.append((name, off, n, shape))
            off += n
        return out

    def unflatten(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        return {
            name: flat[off : off + n].reshape(shape)
            for name, off, n, shape in self.offsets()
        }

    def flatten(self, params: Dict[str, np.ndarray]) -> np.ndarray:
        return np.concatenate([np.asarray(params[n], np.float32).reshape(-1) for n, _ in self.entries])

    def init(self, seed: int) -> np.ndarray:
        """He/Xavier-style init, deterministic in `seed`.

        Weights named `w*` get scaled-gaussian fan-in init; biases (`b*`)
        start at zero except `log_std`, which starts at -0.5 so the power
        policy explores with moderate noise.
        """
        rng = np.random.default_rng(seed)
        chunks = []
        for name, shape in self.entries:
            n = int(np.prod(shape))
            if name.startswith("w"):
                fan_in = shape[0] if len(shape) > 1 else n
                chunks.append(rng.normal(0.0, math.sqrt(1.0 / max(fan_in, 1)), n).astype(np.float32))
            elif "log_std" in name:
                chunks.append(np.full(n, -0.5, np.float32))
            else:
                chunks.append(np.zeros(n, np.float32))
        return np.concatenate(chunks)

    def to_manifest(self) -> List[Dict]:
        return [
            {"name": name, "offset": off, "count": n, "shape": list(shape)}
            for name, off, n, shape in self.offsets()
        ]


def gaussian_log_prob(a: jnp.ndarray, mu: jnp.ndarray, log_std: jnp.ndarray) -> jnp.ndarray:
    """log N(a; mu, exp(log_std)^2), elementwise."""
    std = jnp.exp(log_std)
    z = (a - mu) / std
    return -0.5 * z * z - log_std - 0.5 * jnp.float32(math.log(2.0 * math.pi))


def gaussian_entropy(log_std: jnp.ndarray) -> jnp.ndarray:
    """H of N(mu, std): 0.5 ln(2 pi e) + ln std."""
    return 0.5 * jnp.float32(1.0 + math.log(2.0 * math.pi)) + log_std


def categorical_entropy(probs: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    p = jnp.clip(probs, 1e-8, 1.0)
    return -jnp.sum(p * jnp.log(p), axis=axis)


def adam_step(
    p: jnp.ndarray,
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    t: jnp.ndarray,
    lr: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
):
    """One Adam step on flat vectors. `t` is the 1-based step count (f32)."""
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    mhat = m2 / (1.0 - jnp.power(jnp.float32(b1), t))
    vhat = v2 / (1.0 - jnp.power(jnp.float32(b2), t))
    p2 = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p2, m2, v2
