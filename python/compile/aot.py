"""AOT lowering: every computation the Rust runtime executes, as HLO TEXT.

Interchange is HLO text, NOT serialized HloModuleProto — jax >= 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts (all under artifacts/, indexed by manifest.json):

  rl/actor_fwd_n{N}_b{B}.hlo.txt       (params, state)            -> 4-tuple
  rl/critic_fwd_n{N}_b{B}.hlo.txt      (params, state)            -> 1-tuple
  rl/actor_update_n{N}_b{B}.hlo.txt    (params, m, v, t, lr, ...) -> 6-tuple
  rl/critic_update_n{N}_b{B}.hlo.txt   (params, m, v, t, lr, ...) -> 4-tuple
  models/{model}_full_b{B}.hlo.txt     (weights, image)           -> logits
  models/{model}_front_p{i}.hlo.txt    (weights, image)           -> feature
  models/{model}_back_p{i}.hlo.txt     (weights, feature)         -> logits
  models/{model}_ae_enc_p{i}.hlo.txt   (ae_weights, feature)      -> (codes, lo, hi)
  models/{model}_ae_dec_p{i}.hlo.txt   (ae_weights, codes, lo, hi)-> feature'
  weights/{model}.bin, weights/{model}_ae_p{i}.bin   flat f32 weight files

Network parameters cross the boundary as ONE flat f32 vector per network
(common.ParamSpec / tree order for backbones), so the Rust side needs no
pytree machinery and weight constants never bloat the HLO text.

Usage: python -m compile.aot --out ../artifacts [--rl-only | --models-only]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import datasets, trainer
from .actor_critic import (
    ActorConfig,
    actor_forward,
    actor_spec,
    actor_update,
    critic_forward,
    critic_spec,
    critic_update,
)
from .autoencoder import AeConfig, ae_flatten, ae_unflatten, decode, encode
from .backbones import build as build_backbone
from .profile import write_profiles

MODELS = ("resnet18", "vgg11", "mobilenetv2")
N_RANGE = range(3, 11)       # paper Fig. 10: N in 3..10
N_FULL = 5                   # the N with the full fig9 batch-size matrix
UPDATE_BATCHES_FULL = (128, 256, 512)
UPDATE_BATCH = 256
# Forward batch sizes: B = 1 serves, B > 1 feed the vectorized rollout
# engine (one row per env lane). Keep in sync with rust runtime/artifacts.rs.
FWD_BATCHES = (1, 2, 4, 8, 16, 32)
N_PARTITION = 6              # b in {0..5}
N_CHANNELS = 2


# ----------------------------------------------------------------- lowering
def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def f32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


class Manifest:
    def __init__(self, root: str):
        self.root = root
        self.entries: List[Dict] = []
        self.meta: Dict = {}

    def add(self, name: str, rel_path: str, inputs: List[Dict], outputs: List[Dict], **extra):
        self.entries.append(
            {"name": name, "path": rel_path, "inputs": inputs, "outputs": outputs, **extra}
        )

    def write(self):
        with open(os.path.join(self.root, "manifest.json"), "w") as f:
            json.dump({"artifacts": self.entries, **self.meta}, f, indent=1)


def emit(man: Manifest, name: str, rel: str, text: str, inputs, outputs, **extra):
    path = os.path.join(man.root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    man.add(name, rel, inputs, outputs, **extra)


def io(name: str, *shape, dtype: str = "f32") -> Dict:
    return {"name": name, "shape": list(shape), "dtype": dtype}


# -------------------------------------------------------------- RL artifacts
def emit_rl(man: Manifest, log=print) -> None:
    for n in N_RANGE:
        cfg = ActorConfig(n_ues=n, n_partition=N_PARTITION, n_channels=N_CHANNELS)
        aspec, cspec = actor_spec(cfg), critic_spec(cfg)
        ap, cp = aspec.size, cspec.size
        d = cfg.state_dim
        t0 = time.time()

        # forwards: B = 1 serves, B > 1 batch one state per rollout lane
        for fb in FWD_BATCHES:
            emit(
                man,
                f"actor_fwd_n{n}_b{fb}",
                f"rl/actor_fwd_n{n}_b{fb}.hlo.txt",
                lower(lambda f, s: actor_forward(cfg, f, s), f32(ap), f32(fb, d)),
                [io("params", ap), io("state", fb, d)],
                [io("probs_b", fb, N_PARTITION), io("probs_c", fb, N_CHANNELS), io("mu", fb, 1), io("log_std", fb, 1)],
                n_ues=n,
            )
            emit(
                man,
                f"critic_fwd_n{n}_b{fb}",
                f"rl/critic_fwd_n{n}_b{fb}.hlo.txt",
                lower(lambda f, s: critic_forward(cfg, f, s), f32(cp), f32(fb, d)),
                [io("params", cp), io("state", fb, d)],
                [io("value", fb, 1)],
                n_ues=n,
            )

        batches = UPDATE_BATCHES_FULL if n == N_FULL else (UPDATE_BATCH,)
        for b in batches:
            emit(
                man,
                f"actor_update_n{n}_b{b}",
                f"rl/actor_update_n{n}_b{b}.hlo.txt",
                lower(
                    lambda f, m, v, t, lr, s, ab, ac, apw, olp, adv: actor_update(
                        cfg, f, m, v, t, lr, s, ab, ac, apw, olp, adv
                    ),
                    f32(ap), f32(ap), f32(ap), f32(), f32(),
                    f32(b, d), i32(b), i32(b), f32(b), f32(b), f32(b),
                ),
                [
                    io("params", ap), io("m", ap), io("v", ap), io("t"), io("lr"),
                    io("state", b, d), io("a_b", b, dtype="i32"), io("a_c", b, dtype="i32"),
                    io("a_p", b), io("old_logp", b), io("adv", b),
                ],
                [
                    io("params", ap), io("m", ap), io("v", ap),
                    io("loss"), io("entropy"), io("clip_frac"),
                ],
                n_ues=n,
            )
            emit(
                man,
                f"critic_update_n{n}_b{b}",
                f"rl/critic_update_n{n}_b{b}.hlo.txt",
                lower(
                    lambda f, m, v, t, lr, s, ret: critic_update(cfg, f, m, v, t, lr, s, ret),
                    f32(cp), f32(cp), f32(cp), f32(), f32(), f32(b, d), f32(b),
                ),
                [
                    io("params", cp), io("m", cp), io("v", cp), io("t"), io("lr"),
                    io("state", b, d), io("returns", b),
                ],
                [io("params", cp), io("m", cp), io("v", cp), io("loss")],
                n_ues=n,
            )
        log(f"[aot] rl n={n}: actor_params={ap} critic_params={cp} ({time.time()-t0:.1f}s)")

    man.meta.setdefault("rl", {})
    man.meta["rl"] = {
        "n_range": list(N_RANGE),
        "n_partition": N_PARTITION,
        "n_channels": N_CHANNELS,
        "update_batches": {str(N_FULL): list(UPDATE_BATCHES_FULL), "default": [UPDATE_BATCH]},
        "fwd_batches": list(FWD_BATCHES),
        "specs": {
            str(n): {
                "actor": actor_spec(ActorConfig(n, N_PARTITION, N_CHANNELS)).to_manifest(),
                "critic": critic_spec(ActorConfig(n, N_PARTITION, N_CHANNELS)).to_manifest(),
                "actor_size": actor_spec(ActorConfig(n, N_PARTITION, N_CHANNELS)).size,
                "critic_size": critic_spec(ActorConfig(n, N_PARTITION, N_CHANNELS)).size,
            }
            for n in N_RANGE
        },
    }


# ----------------------------------------------------- backbone param flatten
def tree_leaves_sorted(params) -> List[Tuple[str, np.ndarray]]:
    """Deterministic (path, leaf) order: sorted depth-first dict walk."""
    out = []

    def walk(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], f"{path}/{k}" if path else k)
        else:
            out.append((path, np.asarray(node, np.float32)))

    walk(params, "")
    return out


def tree_flatten_vec(params) -> np.ndarray:
    return np.concatenate([leaf.reshape(-1) for _, leaf in tree_leaves_sorted(params)])


def tree_unflatten_vec(template, flat: jnp.ndarray):
    """Rebuild the nested dict from a flat vector using template's shapes."""
    leaves = tree_leaves_sorted(template)
    offsets = {}
    o = 0
    for path, leaf in leaves:
        offsets[path] = (o, leaf.shape)
        o += leaf.size

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(node[k], f"{path}/{k}" if path else k) for k in sorted(node)}
        off, shape = offsets[path]
        n = int(np.prod(shape)) if shape else 1
        return flat[off : off + n].reshape(shape)

    return walk(template, "")


# ----------------------------------------------------------- model artifacts
def emit_models(man: Manifest, out_root: str, budget=None, log=print) -> None:
    """Train backbones + AEs (once), dump weights, lower segment artifacts."""
    budget = budget or trainer.TrainBudget()
    comp_dir = os.path.join(out_root, "compression")
    weights_dir = os.path.join(out_root, "weights")
    os.makedirs(weights_dir, exist_ok=True)

    selected = os.environ.get("MACCI_MODELS", ",".join(MODELS)).split(",")
    model_meta = {}
    for model in [m for m in MODELS if m in selected]:
        bb, params, points, summary = trainer.run_compression_experiments(
            model, comp_dir, budget, with_xi=(model == "resnet18"), log=log
        )
        template = params
        flat = tree_flatten_vec(params)
        wpath = os.path.join(weights_dir, f"{model}.bin")
        flat.tofile(wpath)
        wsize = flat.size
        hw = bb.input_hw

        def full_fn(w, x):
            p = tree_unflatten_vec(template, w)
            return (bb.forward(p, x),)

        for b in (1, 8):
            emit(
                man,
                f"{model}_full_b{b}",
                f"models/{model}_full_b{b}.hlo.txt",
                lower(full_fn, f32(wsize), f32(b, 3, hw, hw)),
                [io("weights", wsize), io("image", b, 3, hw, hw)],
                [io("logits", b, datasets.NUM_CLASSES)],
                model=model,
            )

        pts_meta = []
        for point in range(1, 5):
            ch, fh, fw = bb.feature_shape(point)
            chosen = points[point - 1]["chosen"]
            cfg: AeConfig = chosen["cfg"]
            ae_flat = ae_flatten({k: np.asarray(v) for k, v in chosen["params"].items()})
            ae_path = os.path.join(weights_dir, f"{model}_ae_p{point}.bin")
            ae_flat.tofile(ae_path)

            def front_fn(w, x, point=point):
                p = tree_unflatten_vec(template, w)
                return (bb.forward_front(p, x, point),)

            def back_fn(w, f, point=point):
                p = tree_unflatten_vec(template, w)
                return (bb.forward_back(p, f, point),)

            def enc_fn(aw, f, cfg=cfg):
                return encode(cfg, ae_unflatten(cfg, aw), f)

            def dec_fn(aw, codes, lo, hi, cfg=cfg):
                return (decode(cfg, ae_unflatten(cfg, aw), codes, lo, hi),)

            emit(
                man, f"{model}_front_p{point}", f"models/{model}_front_p{point}.hlo.txt",
                lower(front_fn, f32(wsize), f32(1, 3, hw, hw)),
                [io("weights", wsize), io("image", 1, 3, hw, hw)],
                [io("feature", 1, ch, fh, fw)], model=model, point=point,
            )
            emit(
                man, f"{model}_back_p{point}", f"models/{model}_back_p{point}.hlo.txt",
                lower(back_fn, f32(wsize), f32(1, ch, fh, fw)),
                [io("weights", wsize), io("feature", 1, ch, fh, fw)],
                [io("logits", 1, datasets.NUM_CLASSES)], model=model, point=point,
            )
            # `bits` rides along so backends can run the quant kernels
            # without consulting the models section (native interpreter)
            emit(
                man, f"{model}_ae_enc_p{point}", f"models/{model}_ae_enc_p{point}.hlo.txt",
                lower(enc_fn, f32(ae_flat.size), f32(1, ch, fh, fw)),
                [io("ae_weights", ae_flat.size), io("feature", 1, ch, fh, fw)],
                [io("codes", 1, cfg.ch_r, fh, fw), io("lo"), io("hi")],
                model=model, point=point, bits=cfg.bits,
            )
            emit(
                man, f"{model}_ae_dec_p{point}", f"models/{model}_ae_dec_p{point}.hlo.txt",
                lower(dec_fn, f32(ae_flat.size), f32(1, cfg.ch_r, fh, fw), f32(), f32()),
                [io("ae_weights", ae_flat.size), io("codes", 1, cfg.ch_r, fh, fw), io("lo"), io("hi")],
                [io("feature", 1, ch, fh, fw)], model=model, point=point, bits=cfg.bits,
            )
            pts_meta.append(
                {
                    "point": point, "ch": ch, "h": fh, "w": fw,
                    "ch_r": cfg.ch_r, "bits": cfg.bits, "rate": cfg.rate,
                    "ae_weights": f"weights/{model}_ae_p{point}.bin",
                    "ae_weights_size": int(ae_flat.size),
                }
            )
            log(f"[aot] {model} p{point}: ch={ch} ch_r={cfg.ch_r} R={cfg.rate:.1f}")

        model_meta[model] = {
            "weights": f"weights/{model}.bin",
            "weights_size": int(wsize),
            "input_hw": hw,
            "num_classes": datasets.NUM_CLASSES,
            "base_acc": summary["base_acc"],
            "points": pts_meta,
        }

    man.meta["models"] = model_meta


# ------------------------------------------------------------------- driver
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--rl-only", action="store_true")
    ap.add_argument("--models-only", action="store_true")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    man = Manifest(out)
    # merge with an existing manifest so rl/models halves can build separately
    prev_path = os.path.join(out, "manifest.json")
    prev = None
    if os.path.exists(prev_path):
        with open(prev_path) as f:
            prev = json.load(f)

    t0 = time.time()
    if not args.models_only:
        emit_rl(man)
    if not args.rl_only:
        emit_models(man, out)
        write_profiles(os.path.join(out, "profiles"), os.path.join(out, "compression"))
    else:
        # profiles can be produced without trained compressors (defaults)
        if not os.path.exists(os.path.join(out, "profiles", "resnet18.json")):
            write_profiles(os.path.join(out, "profiles"), os.path.join(out, "compression"))

    if prev is not None:
        have = {e["name"] for e in man.entries}
        for e in prev.get("artifacts", []):
            if e["name"] not in have:
                man.entries.append(e)
        if "rl" not in man.meta and "rl" in prev:
            man.meta["rl"] = prev["rl"]
        # deep-merge models so partial (MACCI_MODELS=...) rebuilds keep the rest
        merged = dict(prev.get("models", {}))
        merged.update(man.meta.get("models", {}))
        if merged:
            man.meta["models"] = merged
    man.write()
    print(f"[aot] wrote {len(man.entries)} artifacts to {out} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
