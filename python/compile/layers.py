"""Minimal pure-JAX NN layers for the build-time backbones.

No flax/haiku — parameters are plain nested dicts of jnp arrays, and every
layer is a pure function. BatchNorm carries running statistics explicitly:
`train=True` uses batch statistics and returns updated running stats through
the `StatsTape` side channel; `train=False` uses the stored running stats
(this is the mode all AOT-lowered inference artifacts use).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

Params = Dict[str, "jnp.ndarray | Params"]


class StatsTape:
    """Collects BatchNorm running-stat updates during a training forward."""

    def __init__(self, momentum: float = 0.9):
        self.momentum = momentum
        self.updates: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}

    def record(self, path: str, mean: jnp.ndarray, var: jnp.ndarray) -> None:
        self.updates[path] = (mean, var)


def conv_init(rng: np.random.Generator, cin: int, cout: int, k: int) -> Params:
    fan_in = cin * k * k
    w = rng.normal(0.0, math.sqrt(2.0 / fan_in), (cout, cin, k, k)).astype(np.float32)
    return {"w": jnp.asarray(w)}


def bn_init(ch: int) -> Params:
    return {
        "scale": jnp.ones(ch, jnp.float32),
        "bias": jnp.zeros(ch, jnp.float32),
        "mean": jnp.zeros(ch, jnp.float32),
        "var": jnp.ones(ch, jnp.float32),
    }


def dense_init(rng: np.random.Generator, cin: int, cout: int) -> Params:
    w = rng.normal(0.0, math.sqrt(1.0 / cin), (cin, cout)).astype(np.float32)
    return {"w": jnp.asarray(w), "b": jnp.zeros(cout, jnp.float32)}


def conv2d(p: Params, x: jnp.ndarray, stride: int = 1, padding: str = "SAME", groups: int = 1) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding=padding,
        feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def batch_norm(
    p: Params,
    x: jnp.ndarray,
    train: bool,
    tape: Optional[StatsTape] = None,
    path: str = "",
    eps: float = 1e-5,
) -> jnp.ndarray:
    if train:
        mean = jnp.mean(x, axis=(0, 2, 3))
        var = jnp.var(x, axis=(0, 2, 3))
        if tape is not None:
            tape.record(path, mean, var)
    else:
        mean, var = p["mean"], p["var"]
    inv = jax.lax.rsqrt(var + eps)
    return (x - mean[None, :, None, None]) * (inv * p["scale"])[None, :, None, None] + p["bias"][None, :, None, None]


def apply_stats_updates(params: Params, tape: StatsTape) -> Params:
    """Fold the tape's batch stats into the running stats (momentum EMA)."""

    def set_path(tree: Params, path: List[str], mean, var):
        node = tree
        for k in path[:-1]:
            node = node[k]
        bn = dict(node[path[-1]])
        m = tape.momentum
        bn["mean"] = m * bn["mean"] + (1 - m) * mean
        bn["var"] = m * bn["var"] + (1 - m) * var
        node[path[-1]] = bn

    out = _deep_copy_dicts(params)  # copy the dict spine; leaves are shared
    for path, (mean, var) in tape.updates.items():
        set_path(out, path.split("/"), mean, var)
    return out


def _deep_copy_dicts(p):
    if isinstance(p, dict):
        return {k: _deep_copy_dicts(v) for k, v in p.items()}
    return p


def relu(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x, 0.0)


def relu6(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(x, 0.0, 6.0)


def max_pool(x: jnp.ndarray, k: int = 2, stride: int = 2) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, stride, stride), "VALID"
    )


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(2, 3))


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"] + p["b"]


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over the batch; labels are int32 class ids."""
    z = logits - jax.scipy.special.logsumexp(logits, axis=-1, keepdims=True)
    return -jnp.mean(z[jnp.arange(logits.shape[0]), labels])
