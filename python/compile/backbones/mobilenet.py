"""MobileNetV2 (Sandler et al.) with the paper's partition points.

Paper Sec. 6.5: "For MobileNetV2, we select 4 partitioning points after the
last batch normalization layer of residual blocks containing a downsampling
layer." MobileNetV2 has four stride-2 inverted-residual blocks (the stem
conv is also stride 2 at paper scale but is not a residual block); the cuts
land after each of those four blocks.

Modules: stem conv, 17 inverted-residual blocks, head conv + classifier.
Demo scale halves widths and uses stride 1 in the stem (32x32 input).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from ..layers import (
    Params,
    batch_norm,
    bn_init,
    conv2d,
    conv_init,
    dense_init,
    global_avg_pool,
    linear,
    relu6,
)
from .base import Backbone, ModuleStat

# (expansion t, out channels c, repeats n, stride s) — the paper's Table 2.
_CFG = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def _make_divisible(v: float, divisor: int = 8) -> int:
    return max(divisor, int(v + divisor / 2) // divisor * divisor)


class MobileNetV2(Backbone):
    name = "mobilenetv2"

    def _build(self):
        w = self.width_mult
        self.stem_ch = _make_divisible(32 * w)
        self.head_ch = _make_divisible(1280 * w) if self.scale == "paper" else _make_divisible(640 * w)
        mods = [("stem", self._stem_fwd, self._stem_stat)]
        self._block_cfg: List[Dict] = []
        points = []
        cin = self.stem_ch
        bi = 0
        for t, c, n, s in _CFG:
            cout = _make_divisible(c * w)
            for i in range(n):
                stride = s if i == 0 else 1
                if self.scale == "demo" and len(self._block_cfg) < 2:
                    stride = 1  # keep early resolution at 32x32 scale
                cfg = {
                    "idx": bi,
                    "cin": cin,
                    "cout": cout,
                    "t": t,
                    "stride": stride,
                    "residual": stride == 1 and cin == cout,
                }
                self._block_cfg.append(cfg)
                mods.append((f"blk{bi}", self._block_fwd(cfg), self._block_stat(cfg)))
                if stride == 2:
                    points.append(len(mods))  # cut after this downsampling block
                cin = cout
                bi += 1
        mods.append(("head", self._head_fwd, self._head_stat))
        self._modules = mods
        # exactly 4 downsampling blocks exist at paper scale; demo scale
        # suppresses the first two strides, so pad/truncate to 4 cuts.
        while len(points) < 4:
            points.insert(0, max(2, points[0] - 2) if points else 2)
        self._points = points[:4]
        self._last_ch = cin

    # -- stem --------------------------------------------------------------
    def _stem_fwd(self, p, x, train, tape):
        stride = 2 if self.scale == "paper" else 1
        x = conv2d(p["stem_conv"], x, stride=stride)
        x = batch_norm(p["stem_bn"], x, train, tape, "stem_bn")
        return relu6(x)

    def _stem_stat(self, in_shape):
        _, h, _ = in_shape
        stride = 2 if self.scale == "paper" else 1
        ho = h // stride
        return ModuleStat("stem", 2.0 * 3 * self.stem_ch * 9 * ho * ho, 3 * self.stem_ch * 9, (self.stem_ch, ho, ho), "conv")

    # -- inverted residual ---------------------------------------------------
    def _block_fwd(self, cfg):
        key = f"blk{cfg['idx']}"

        def fwd(p, x, train, tape):
            blk = p[key]
            mid = cfg["cin"] * cfg["t"]
            out = x
            if cfg["t"] != 1:
                out = conv2d(blk["expand"], out, stride=1)
                out = batch_norm(blk["expand_bn"], out, train, tape, f"{key}/expand_bn")
                out = relu6(out)
            out = conv2d(blk["dw"], out, stride=cfg["stride"], groups=mid)
            out = batch_norm(blk["dw_bn"], out, train, tape, f"{key}/dw_bn")
            out = relu6(out)
            out = conv2d(blk["project"], out, stride=1)
            out = batch_norm(blk["project_bn"], out, train, tape, f"{key}/project_bn")
            if cfg["residual"]:
                out = out + x
            return out

        return fwd

    def _block_stat(self, cfg):
        def stat(in_shape):
            cin, h, _ = in_shape
            mid = cfg["cin"] * cfg["t"]
            ho = h // cfg["stride"]
            fl = 0.0
            pr = 0
            if cfg["t"] != 1:
                fl += 2.0 * cin * mid * h * h
                pr += cin * mid
            fl += 2.0 * mid * 9 * ho * ho          # depthwise
            pr += mid * 9
            fl += 2.0 * mid * cfg["cout"] * ho * ho
            pr += mid * cfg["cout"]
            return ModuleStat(f"blk{cfg['idx']}", fl, pr, (cfg["cout"], ho, ho), "conv")

        return stat

    # -- head ------------------------------------------------------------------
    def _head_fwd(self, p, x, train, tape):
        x = conv2d(p["head_conv"], x, stride=1)
        x = batch_norm(p["head_bn"], x, train, tape, "head_bn")
        x = relu6(x)
        return linear(p["fc"], global_avg_pool(x))

    def _head_stat(self, in_shape):
        cin, h, _ = in_shape
        fl = 2.0 * cin * self.head_ch * h * h + 2.0 * self.head_ch * self.num_classes
        pr = cin * self.head_ch + self.head_ch * self.num_classes
        return ModuleStat("head", fl, pr, (self.num_classes, 1, 1), "fc")

    def init(self, seed: int) -> Params:
        rng = np.random.default_rng(seed)
        params: Dict = {
            "stem_conv": conv_init(rng, 3, self.stem_ch, 3),
            "stem_bn": bn_init(self.stem_ch),
        }
        for cfg in self._block_cfg:
            key = f"blk{cfg['idx']}"
            mid = cfg["cin"] * cfg["t"]
            blk: Dict = {}
            if cfg["t"] != 1:
                blk["expand"] = conv_init(rng, cfg["cin"], mid, 1)
                blk["expand_bn"] = bn_init(mid)
            # depthwise: OIHW with I = 1 (feature_group_count = mid)
            dw = conv_init(rng, 1, mid, 3)
            blk["dw"] = dw
            blk["dw_bn"] = bn_init(mid)
            blk["project"] = conv_init(rng, mid, cfg["cout"], 1)
            blk["project_bn"] = bn_init(cfg["cout"])
            params[key] = blk
        params["head_conv"] = conv_init(rng, self._last_ch, self.head_ch, 1)
        params["head_bn"] = bn_init(self.head_ch)
        params["fc"] = dense_init(rng, self.head_ch, self.num_classes)
        return params
