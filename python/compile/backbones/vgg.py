"""VGG11 (Simonyan & Zisserman) with partition points after MaxPool layers.

Paper Sec. 6.5: "For VGG11, we select 4 partitioning points after MaxPool
layers." VGG11 has five maxpools; we cut after the first four (the fifth
leaves only the classifier head behind, which is never a useful split).

Modules: conv(+bn)+relu and maxpool units, then the classifier head. BN is
not in the original VGG11 but stabilizes the short build-time training run;
it is folded into the conv module (VGG-BN variant, standard in torchvision).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from ..layers import (
    Params,
    batch_norm,
    bn_init,
    conv2d,
    conv_init,
    dense_init,
    global_avg_pool,
    linear,
    max_pool,
    relu,
)
from .base import Backbone, ModuleStat

# VGG11 config "A": (channels, then M = maxpool)
_CFG = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


class VGG11(Backbone):
    name = "vgg11"

    def _build(self):
        w = self.width_mult
        mods = []
        self._chans: List[int] = []
        conv_idx = 0
        pool_count = 0
        points = []
        for item in _CFG:
            if item == "M":
                mods.append((f"pool{pool_count}", self._pool_fwd, self._pool_stat))
                pool_count += 1
                if pool_count <= 4:
                    points.append(len(mods))  # cut right after this pool
            else:
                ch = max(8, int(item * w))
                self._chans.append(ch)
                mods.append(
                    (f"conv{conv_idx}", self._conv_fwd(conv_idx), self._conv_stat(conv_idx, ch))
                )
                conv_idx += 1
        mods.append(("head", self._head_fwd, self._head_stat))
        self._modules = mods
        self._points = points

    def _conv_fwd(self, i):
        key = f"conv{i}"

        def fwd(p, x, train, tape):
            x = conv2d(p[key], x, stride=1)
            x = batch_norm(p[f"bn{i}"], x, train, tape, f"bn{i}")
            return relu(x)

        return fwd

    def _conv_stat(self, i, cout):
        def stat(in_shape):
            cin, h, _ = in_shape
            return ModuleStat(f"conv{i}", 2.0 * cin * cout * 9 * h * h, cin * cout * 9, (cout, h, h), "conv")

        return stat

    def _pool_fwd(self, p, x, train, tape):
        return max_pool(x, 2, 2)

    def _pool_stat(self, in_shape):
        c, h, _ = in_shape
        return ModuleStat("pool", c * h * h, 0, (c, h // 2, h // 2), "pool")

    def _head_fwd(self, p, x, train, tape):
        return linear(p["fc"], global_avg_pool(x))

    def _head_stat(self, in_shape):
        cin, _, _ = in_shape
        return ModuleStat("head", 2.0 * cin * self.num_classes, cin * self.num_classes, (self.num_classes, 1, 1), "fc")

    def init(self, seed: int) -> Params:
        rng = np.random.default_rng(seed)
        params: Dict = {}
        cin = 3
        for i, ch in enumerate(self._chans):
            params[f"conv{i}"] = conv_init(rng, cin, ch, 3)
            params[f"bn{i}"] = bn_init(ch)
            cin = ch
        params["fc"] = dense_init(rng, cin, self.num_classes)
        return params
