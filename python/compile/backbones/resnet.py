"""ResNet18 (He et al. 2016) with the paper's four partition points.

The paper (Sec. 6.1) partitions ResNet18 at "the output end of the second
layer in each stage, i.e. the batch normalization layer" — one point after
the second basic block of each of the four stages. Modules here are the
indivisible units of Sec. 3.2: the stem, then eight residual blocks, then
the pooled classifier head.

Demo scale uses the standard CIFAR-style stem (3x3 conv, no maxpool) at half
width; paper scale uses the ImageNet stem (7x7/2 conv + 3x3/2 maxpool) at
full width. Partition indices are identical in both.
"""
from __future__ import annotations

import math
from typing import Dict

import numpy as np
import jax.numpy as jnp

from ..layers import (
    Params,
    StatsTape,
    batch_norm,
    bn_init,
    conv2d,
    conv_init,
    dense_init,
    global_avg_pool,
    linear,
    max_pool,
    relu,
)
from .base import Backbone, ModuleStat


def _conv_flops(cin, cout, k, hw_out, groups=1):
    return 2.0 * cin * cout * k * k * hw_out * hw_out / groups


class ResNet18(Backbone):
    name = "resnet18"

    def _build(self):
        w = self.width_mult
        self.stage_ch = [max(8, int(c * w)) for c in (64, 128, 256, 512)]
        self.stem_ch = self.stage_ch[0]
        mods = []

        if self.scale == "paper":
            mods.append(("stem", self._stem_paper_fwd, self._stem_paper_stat))
        else:
            mods.append(("stem", self._stem_demo_fwd, self._stem_demo_stat))

        for si, ch in enumerate(self.stage_ch):
            for bi in range(2):
                stride = 2 if (si > 0 and bi == 0) else 1
                mods.append(
                    (
                        f"s{si}b{bi}",
                        self._block_fwd(si, bi, stride),
                        self._block_stat(si, bi, stride),
                    )
                )
        mods.append(("head", self._head_fwd, self._head_stat))
        self._modules = mods
        # cut AFTER the 2nd block of each stage: module list is
        # [stem, s0b0, s0b1, s1b0, s1b1, s2b0, s2b1, s3b0, s3b1, head]
        self._points = [3, 5, 7, 9]

    # -- stem -------------------------------------------------------------
    def _stem_demo_fwd(self, p, x, train, tape):
        x = conv2d(p["stem_conv"], x, stride=1)
        x = batch_norm(p["stem_bn"], x, train, tape, "stem_bn")
        return relu(x)

    def _stem_demo_stat(self, in_shape):
        _, h, _ = in_shape
        return ModuleStat("stem", _conv_flops(3, self.stem_ch, 3, h), 3 * self.stem_ch * 9, (self.stem_ch, h, h), "conv")

    def _stem_paper_fwd(self, p, x, train, tape):
        x = conv2d(p["stem_conv"], x, stride=2)
        x = batch_norm(p["stem_bn"], x, train, tape, "stem_bn")
        x = relu(x)
        return max_pool(x, 3, 2) if x.shape[2] >= 4 else x

    def _stem_paper_stat(self, in_shape):
        _, h, _ = in_shape
        h2 = h // 4
        return ModuleStat("stem", _conv_flops(3, self.stem_ch, 7, h // 2), 3 * self.stem_ch * 49, (self.stem_ch, h2, h2), "conv")

    # -- residual blocks ----------------------------------------------------
    def _block_fwd(self, si, bi, stride):
        key = f"s{si}b{bi}"

        def fwd(p, x, train, tape):
            blk = p[key]
            out = conv2d(blk["conv1"], x, stride=stride)
            out = batch_norm(blk["bn1"], out, train, tape, f"{key}/bn1")
            out = relu(out)
            out = conv2d(blk["conv2"], out, stride=1)
            out = batch_norm(blk["bn2"], out, train, tape, f"{key}/bn2")
            if "down_conv" in blk:
                x = conv2d(blk["down_conv"], x, stride=stride)
                x = batch_norm(blk["down_bn"], x, train, tape, f"{key}/down_bn")
            return relu(out + x)

        return fwd

    def _block_stat(self, si, bi, stride):
        def stat(in_shape):
            cin, h, _ = in_shape
            cout = self.stage_ch[si]
            ho = h // stride
            fl = _conv_flops(cin, cout, 3, ho) + _conv_flops(cout, cout, 3, ho)
            pr = cin * cout * 9 + cout * cout * 9
            if stride != 1 or cin != cout:
                fl += _conv_flops(cin, cout, 1, ho)
                pr += cin * cout
            return ModuleStat(f"s{si}b{bi}", fl, pr, (cout, ho, ho), "conv")

        return stat

    # -- head --------------------------------------------------------------
    def _head_fwd(self, p, x, train, tape):
        return linear(p["fc"], global_avg_pool(x))

    def _head_stat(self, in_shape):
        cin, _, _ = in_shape
        return ModuleStat("head", 2.0 * cin * self.num_classes, cin * self.num_classes, (self.num_classes, 1, 1), "fc")

    # -- init ----------------------------------------------------------------
    def init(self, seed: int) -> Params:
        rng = np.random.default_rng(seed)
        k_stem = 7 if self.scale == "paper" else 3
        params: Dict = {
            "stem_conv": conv_init(rng, 3, self.stem_ch, k_stem),
            "stem_bn": bn_init(self.stem_ch),
        }
        cin = self.stem_ch
        for si, ch in enumerate(self.stage_ch):
            for bi in range(2):
                stride = 2 if (si > 0 and bi == 0) else 1
                blk: Dict = {
                    "conv1": conv_init(rng, cin, ch, 3),
                    "bn1": bn_init(ch),
                    "conv2": conv_init(rng, ch, ch, 3),
                    "bn2": bn_init(ch),
                }
                if stride != 1 or cin != ch:
                    blk["down_conv"] = conv_init(rng, cin, ch, 1)
                    blk["down_bn"] = bn_init(ch)
                params[f"s{si}b{bi}"] = blk
                cin = ch
        params["fc"] = dense_init(rng, cin, self.num_classes)
        return params
