"""Backbone zoo: ResNet18, VGG11, MobileNetV2 with the paper's partition points.

Each backbone exposes the same structural interface (`Backbone`): an ordered
list of coarse *modules* (the indivisible units of Sec. 3.2 — layers or
residual blocks), four partition points chosen exactly as the paper does
(Sec. 6.1 / 6.5), and segment-wise forward functions so the AOT path can
lower `front_p{i}` / `back_p{i}` HLO artifacts for collaborative inference.

Two scales are supported from the same architecture description:
  * "demo"  — 32x32 input, reduced width: these are actually trained and
    executed on the CPU PJRT runtime (serving example, compression sweeps);
  * "paper" — 224x224 input, full width: never executed, used analytically
    by profile.py to produce the paper-scale FLOPs/feature-size tables that
    drive the MDP simulation (Jetson-class overhead model).
"""
from .base import Backbone, ModuleStat
from .resnet import ResNet18
from .vgg import VGG11
from .mobilenet import MobileNetV2

REGISTRY = {
    "resnet18": ResNet18,
    "vgg11": VGG11,
    "mobilenetv2": MobileNetV2,
}


def build(name: str, scale: str = "demo", num_classes: int = 16) -> Backbone:
    try:
        cls = REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown backbone '{name}' (have {sorted(REGISTRY)})")
    return cls(scale=scale, num_classes=num_classes)
