"""Common structural interface for all backbones.

A backbone is an ordered list of coarse modules (the paper's indivisible
inference units, Sec. 3.2). Each module reports:
  * a forward function over (params, x, train, tape),
  * an analytic `stat(hw)` giving FLOPs / output shape at spatial size hw —
    used by profile.py for the paper-scale device model without executing
    anything.

Partition points are indices into the module list: partition point i means
"UE executes modules [0, cut_i), the edge executes [cut_i, end)"; the
intermediate feature is the output of module cut_i - 1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from ..layers import Params, StatsTape


@dataclass
class ModuleStat:
    """Analytic per-module cost at a given input spatial size."""

    name: str
    flops: float                 # multiply-accumulates * 2
    params: int
    out_shape: Tuple[int, int, int]  # (C, H, W) after this module
    kind: str = "conv"           # conv | fc | pool — drives the parallelism
    #                              factor in the device power model


class Backbone:
    """Base class; subclasses populate self._modules and self._points."""

    name: str = "base"

    def __init__(self, scale: str = "demo", num_classes: int = 16):
        assert scale in ("demo", "paper")
        self.scale = scale
        self.num_classes = num_classes
        self.input_hw = 32 if scale == "demo" else 224
        self.width_mult = 0.5 if scale == "demo" else 1.0
        # populated by subclass:
        self._modules: List[Tuple[str, Callable, Callable]] = []  # (name, fwd, stat)
        self._points: List[int] = []  # 4 cut indices into self._modules
        self._build()

    # -- subclass hooks -------------------------------------------------
    def _build(self) -> None:
        raise NotImplementedError

    def init(self, seed: int) -> Params:
        raise NotImplementedError

    # -- structural queries ---------------------------------------------
    @property
    def num_modules(self) -> int:
        return len(self._modules)

    @property
    def partition_points(self) -> List[int]:
        """4 cut indices; partition decision b in {0..5}: 0 = raw offload,
        1..4 = these cuts, 5 = full local."""
        return list(self._points)

    def module_stats(self) -> List[ModuleStat]:
        """Analytic stats, chained through the network at self.input_hw."""
        stats: List[ModuleStat] = []
        shape = (3, self.input_hw, self.input_hw)
        for name, _fwd, stat in self._modules:
            st = stat(shape)
            stats.append(st)
            shape = st.out_shape
        return stats

    def feature_shape(self, point: int) -> Tuple[int, int, int]:
        """(C, H, W) of the intermediate feature at partition point (1-based)."""
        cut = self._points[point - 1]
        return self.module_stats()[cut - 1].out_shape

    # -- forwards ---------------------------------------------------------
    def forward_range(
        self,
        params: Params,
        x: jnp.ndarray,
        start: int,
        end: int,
        train: bool = False,
        tape: Optional[StatsTape] = None,
    ) -> jnp.ndarray:
        for name, fwd, _stat in self._modules[start:end]:
            x = fwd(params, x, train, tape)
        return x

    def forward(self, params: Params, x, train: bool = False, tape: Optional[StatsTape] = None):
        return self.forward_range(params, x, 0, self.num_modules, train, tape)

    def forward_front(self, params: Params, x, point: int, train: bool = False, tape=None):
        """Modules [0, cut) — the UE-side segment for partition point (1-based)."""
        return self.forward_range(params, x, 0, self._points[point - 1], train, tape)

    def forward_back(self, params: Params, feat, point: int, train: bool = False, tape=None):
        """Modules [cut, end) — the edge-side segment."""
        return self.forward_range(params, feat, self._points[point - 1], self.num_modules, train, tape)
