"""Backbone structural tests: shapes, split consistency, analytic stats."""
import numpy as np
import pytest
import jax.numpy as jnp

from compile.backbones import build, REGISTRY

MODELS = sorted(REGISTRY)


@pytest.mark.parametrize("model", MODELS)
def test_four_partition_points(model):
    for scale in ("demo", "paper"):
        bb = build(model, scale)
        assert len(bb.partition_points) == 4
        assert all(0 < p < bb.num_modules for p in bb.partition_points)
        assert sorted(bb.partition_points) == bb.partition_points


@pytest.mark.parametrize("model", MODELS)
def test_module_stats_chain(model):
    bb = build(model, "paper")
    stats = bb.module_stats()
    assert len(stats) == bb.num_modules
    assert all(s.flops > 0 for s in stats)
    # final module produces the classifier output
    assert stats[-1].out_shape[0] == bb.num_classes


@pytest.mark.parametrize("model", MODELS)
def test_front_back_split_equals_full(model):
    bb = build(model, "demo")
    params = bb.init(0)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 3, 32, 32)), jnp.float32)
    full = bb.forward(params, x)
    assert full.shape == (2, bb.num_classes)
    for p in range(1, 5):
        feat = bb.forward_front(params, x, p)
        ch, h, w = bb.feature_shape(p)
        assert feat.shape == (2, ch, h, w), (model, p)
        out = bb.forward_back(params, feat, p)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full), rtol=1e-4, atol=1e-4)


def test_paper_scale_flops_anchor():
    """Sanity anchors against published FLOPs (2*MACs)."""
    r = sum(s.flops for s in build("resnet18", "paper").module_stats()) / 1e9
    v = sum(s.flops for s in build("vgg11", "paper").module_stats()) / 1e9
    m = sum(s.flops for s in build("mobilenetv2", "paper").module_stats()) / 1e9
    assert 3.0 < r < 4.5, r      # ResNet18 ~3.6 GFLOPs
    assert 13.0 < v < 17.0, v    # VGG11 ~15.2 GFLOPs
    assert 0.4 < m < 0.8, m      # MobileNetV2 ~0.6 GFLOPs


def test_feature_shapes_paper_scale():
    bb = build("resnet18", "paper")
    assert bb.feature_shape(1) == (64, 56, 56)
    assert bb.feature_shape(4) == (512, 7, 7)


def test_unknown_model_rejected():
    with pytest.raises(KeyError):
        build("alexnet")
