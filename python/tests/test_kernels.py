"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; assert_allclose against ref.py
is THE core correctness signal for everything the AOT artifacts execute.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dense import dense
from compile.kernels.conv1x1 import conv1x1
from compile.kernels import quant

SETTINGS = dict(max_examples=25, deadline=None)


def arr(rng, shape, lo=-3.0, hi=3.0):
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(np.float32))


# ---------------------------------------------------------------- dense
@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2, 3, 8, 128, 256]),
    cin=st.sampled_from([1, 4, 20, 64, 256]),
    cout=st.sampled_from([1, 2, 6, 64, 128]),
    act=st.sampled_from(["linear", "tanh", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(b, cin, cout, act, seed):
    rng = np.random.default_rng(seed)
    x, w, bias = arr(rng, (b, cin)), arr(rng, (cin, cout)), arr(rng, (cout,))
    got = dense(x, w, bias, act)
    want = ref.dense_ref(x, w, bias, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dense_grad_matches_jnp_grad():
    rng = np.random.default_rng(0)
    x, w, b = arr(rng, (8, 16)), arr(rng, (16, 4)), arr(rng, (4,))

    def f_pallas(x, w, b):
        return jnp.sum(dense(x, w, b, "tanh") ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.dense_ref(x, w, b, "tanh") ** 2)

    g1 = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)


def test_dense_under_jit():
    rng = np.random.default_rng(1)
    x, w, b = arr(rng, (128, 20)), arr(rng, (20, 6)), arr(rng, (6,))
    got = jax.jit(lambda *a: dense(*a, "relu"))(x, w, b)
    np.testing.assert_allclose(got, ref.dense_ref(x, w, b, "relu"), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- conv1x1
@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 2, 4]),
    c=st.sampled_from([1, 3, 16, 64]),
    c2=st.sampled_from([1, 2, 8, 32]),
    hw=st.sampled_from([1, 2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv1x1_matches_ref(n, c, c2, hw, seed):
    rng = np.random.default_rng(seed)
    x, w, b = arr(rng, (n, c, hw, hw)), arr(rng, (c, c2)), arr(rng, (c2,))
    got = conv1x1(x, w, b)
    want = ref.conv1x1_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_conv1x1_equals_lax_conv():
    """Cross-check against an actual 1x1 convolution."""
    rng = np.random.default_rng(3)
    x, w, b = arr(rng, (2, 8, 5, 5)), arr(rng, (8, 4)), arr(rng, (4,))
    got = conv1x1(x, w, b)
    kernel = w.T.reshape(4, 8, 1, 1)  # OIHW
    want = jax.lax.conv_general_dilated(
        x, kernel, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
    ) + b[None, :, None, None]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_conv1x1_grads_flow():
    rng = np.random.default_rng(4)
    x, w, b = arr(rng, (2, 6, 4, 4)), arr(rng, (6, 3)), arr(rng, (3,))

    def loss(w, b):
        return jnp.mean((conv1x1(x, w, b) - 1.0) ** 2)

    def loss_ref(w, b):
        return jnp.mean((ref.conv1x1_ref(x, w, b) - 1.0) ** 2)

    g = jax.grad(loss, argnums=(0, 1))(w, b)
    ge = jax.grad(loss_ref, argnums=(0, 1))(w, b)
    for a, e in zip(g, ge):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- quant
@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 4, 100, 1024, 1000]),
    bits=st.sampled_from([2, 4, 8, 12]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_roundtrip_matches_ref(n, bits, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (n,), -5.0, 5.0)
    lo, hi = jnp.min(x), jnp.max(x)
    q = quant.quantize(x, lo, hi, bits)
    q_ref = ref.quantize_ref(x, lo, hi, bits)
    np.testing.assert_allclose(q, q_ref, atol=0.0)
    d = quant.dequantize(q, lo, hi, bits)
    d_ref = ref.dequantize_ref(q_ref, lo, hi, bits)
    np.testing.assert_allclose(d, d_ref, rtol=1e-6, atol=1e-6)
    # round-off bounded by half a step
    step = float(hi - lo) / (2**bits - 1)
    assert float(jnp.max(jnp.abs(d - x))) <= step / 2 + 1e-5


def test_quant_codes_are_integers_in_range():
    rng = np.random.default_rng(7)
    x = arr(rng, (512,), -1.0, 1.0)
    q = np.asarray(quant.quantize(x, jnp.float32(-1), jnp.float32(1), 8))
    assert np.all(q == np.round(q))
    assert q.min() >= 0 and q.max() <= 255


def test_quantize_ste_identity_gradient():
    rng = np.random.default_rng(8)
    x = arr(rng, (64,), -2.0, 2.0)
    g = jax.grad(lambda v: jnp.sum(quant.quantize_ste(v, jnp.min(v), jnp.max(v), 8)))(x)
    np.testing.assert_allclose(g, jnp.ones_like(x), atol=1e-6)


def test_quant_degenerate_range():
    x = jnp.zeros(16)
    q = quant.quantize(x, jnp.float32(0), jnp.float32(0), 8)
    assert bool(jnp.all(jnp.isfinite(q)))
