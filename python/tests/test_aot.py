"""AOT pipeline: HLO-text lowering, manifest consistency, weight files.

Fast checks only — full artifact generation is `make artifacts`. If an
artifacts/ tree exists these tests validate it; the lowering smoke test
always runs.
"""
import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot
from compile.actor_critic import ActorConfig, actor_forward, actor_spec

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_produces_parseable_hlo_text():
    cfg = ActorConfig(n_ues=3, n_partition=6, n_channels=2)
    spec = actor_spec(cfg)
    text = aot.lower(
        lambda f, s: actor_forward(cfg, f, s),
        aot.f32(spec.size),
        aot.f32(1, cfg.state_dim),
    )
    assert "HloModule" in text
    assert "ROOT" in text
    # tuple-rooted (return_tuple=True) so the rust side can decompose
    assert "tuple(" in text.replace(" ", "")


def test_tree_flatten_roundtrip():
    tree = {"b": {"x": np.ones((2, 2), np.float32)}, "a": np.arange(3, dtype=np.float32)}
    flat = aot.tree_flatten_vec(tree)
    assert flat.shape == (7,)
    back = aot.tree_unflatten_vec(tree, jnp.asarray(flat))
    np.testing.assert_array_equal(np.asarray(back["a"]), tree["a"])
    np.testing.assert_array_equal(np.asarray(back["b"]["x"]), tree["b"]["x"])
    # deterministic order: 'a' before 'b'
    assert flat[0] == 0.0 and flat[1] == 1.0


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)


@needs_artifacts
def test_manifest_artifacts_exist_on_disk():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    assert len(man["artifacts"]) >= 40
    for e in man["artifacts"]:
        path = os.path.join(ARTIFACTS, e["path"])
        assert os.path.exists(path), e["name"]
        assert e["inputs"] and e["outputs"]


@needs_artifacts
def test_manifest_rl_specs_match_sizes():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    for n_str, spec in man["rl"]["specs"].items():
        cfg = ActorConfig(int(n_str), man["rl"]["n_partition"], man["rl"]["n_channels"])
        assert spec["actor_size"] == actor_spec(cfg).size
        total = sum(e["count"] for e in spec["actor"])
        assert total == spec["actor_size"]


@needs_artifacts
def test_weight_files_match_declared_sizes():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    for name, m in man.get("models", {}).items():
        w = os.path.join(ARTIFACTS, m["weights"])
        assert os.path.getsize(w) == m["weights_size"] * 4, name
        for p in m["points"]:
            ae = os.path.join(ARTIFACTS, p["ae_weights"])
            assert os.path.getsize(ae) == p["ae_weights_size"] * 4
