"""L2 correctness: actor/critic networks, hybrid log-probs, PPO updates."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.actor_critic import (
    ActorConfig,
    actor_forward,
    actor_loss,
    actor_spec,
    actor_update,
    critic_forward,
    critic_spec,
    critic_update,
    hybrid_log_prob,
)

CFG = ActorConfig(n_ues=5, n_partition=6, n_channels=2)


@pytest.fixture(scope="module")
def params():
    return jnp.asarray(actor_spec(CFG).init(0))


@pytest.fixture(scope="module")
def cparams():
    return jnp.asarray(critic_spec(CFG).init(1))


def states(b, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(b, CFG.state_dim)), jnp.float32)


def test_spec_sizes_consistent():
    spec = actor_spec(CFG)
    assert spec.size == sum(int(np.prod(s)) for _, s in spec.entries)
    offs = spec.offsets()
    assert offs[0][1] == 0
    for (_, o1, n1, _), (_, o2, _, _2) in zip(offs, offs[1:]):
        assert o2 == o1 + n1


def test_actor_outputs_valid_distributions(params):
    pb, pc, mu, ls = actor_forward(CFG, params, states(16))
    assert pb.shape == (16, 6) and pc.shape == (16, 2)
    np.testing.assert_allclose(pb.sum(-1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(pc.sum(-1), 1.0, rtol=1e-5)
    assert bool(jnp.all(pb >= 0)) and bool(jnp.all(pc >= 0))
    assert bool(jnp.all(ls >= -4.0)) and bool(jnp.all(ls <= 1.0))


def test_hybrid_log_prob_decomposes(params):
    s = states(8)
    ab = jnp.arange(8, dtype=jnp.int32) % 6
    ac = jnp.arange(8, dtype=jnp.int32) % 2
    ap = jnp.linspace(-1, 1, 8, dtype=jnp.float32)
    logp, ent = hybrid_log_prob(CFG, params, s, ab, ac, ap)
    pb, pc, mu, ls = actor_forward(CFG, params, s)
    for i in range(8):
        std = float(jnp.exp(ls[i, 0]))
        z = (float(ap[i]) - float(mu[i, 0])) / std
        lp = (
            np.log(max(float(pb[i, ab[i]]), 1e-8))
            + np.log(max(float(pc[i, ac[i]]), 1e-8))
            + (-0.5 * z * z - float(ls[i, 0]) - 0.5 * np.log(2 * np.pi))
        )
        np.testing.assert_allclose(float(logp[i]), lp, rtol=1e-4, atol=1e-5)
    assert bool(jnp.all(ent > 0.0))


def test_actor_loss_zero_adv_gives_entropy_only(params):
    s = states(4)
    ab = jnp.zeros(4, jnp.int32)
    ac = jnp.zeros(4, jnp.int32)
    ap = jnp.zeros(4, jnp.float32)
    logp, _ = hybrid_log_prob(CFG, params, s, ab, ac, ap)
    loss, (ent, cf) = actor_loss(CFG, params, s, ab, ac, ap, logp, jnp.zeros(4), 0.2, 0.001)
    # with adv = 0 and ratio = 1: loss = -(0 + zeta*H)
    np.testing.assert_allclose(float(loss), -0.001 * float(ent), rtol=1e-4)
    assert float(cf) == 0.0


def test_actor_update_improves_selected_action_probability(params):
    s = states(64, seed=3)
    ab = jnp.full(64, 3, jnp.int32)
    ac = jnp.full(64, 1, jnp.int32)
    ap = jnp.zeros(64, jnp.float32)
    logp, _ = hybrid_log_prob(CFG, params, s, ab, ac, ap)
    adv = jnp.ones(64, jnp.float32)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    p = params
    for t in range(1, 6):
        p, m, v, loss, ent, cf = actor_update(
            CFG, p, m, v, jnp.float32(t), jnp.float32(3e-3), s, ab, ac, ap, logp, adv
        )
    pb_new, pc_new, _, _ = actor_forward(CFG, p, s)
    pb_old, pc_old, _, _ = actor_forward(CFG, params, s)
    assert float(pb_new[:, 3].mean()) > float(pb_old[:, 3].mean())
    assert float(pc_new[:, 1].mean()) > float(pc_old[:, 1].mean())


def test_critic_update_fits_constant_target(cparams):
    s = states(32, seed=5)
    target = jnp.full(32, -2.5, jnp.float32)
    p, m, v = cparams, jnp.zeros_like(cparams), jnp.zeros_like(cparams)
    first = None
    for t in range(1, 40):
        p, m, v, loss = critic_update(
            CFG, p, m, v, jnp.float32(t), jnp.float32(1e-2), s, target
        )
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.2, (first, float(loss))
    pred = critic_forward(CFG, p, s)
    assert abs(float(pred.mean()) + 2.5) < 0.6


def test_update_is_deterministic(params):
    s = states(8, seed=9)
    args = (
        jnp.zeros(8, jnp.int32),
        jnp.ones(8, jnp.int32),
        jnp.zeros(8, jnp.float32),
        jnp.zeros(8, jnp.float32),
        jnp.ones(8, jnp.float32),
    )
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    o1 = actor_update(CFG, params, m, v, jnp.float32(1), jnp.float32(1e-4), s, *args)
    o2 = actor_update(CFG, params, m, v, jnp.float32(1), jnp.float32(1e-4), s, *args)
    np.testing.assert_array_equal(np.asarray(o1[0]), np.asarray(o2[0]))


@pytest.mark.parametrize("n", [3, 7, 10])
def test_other_ue_counts(n):
    cfg = ActorConfig(n_ues=n, n_partition=6, n_channels=2)
    p = jnp.asarray(actor_spec(cfg).init(2))
    s = jnp.zeros((2, cfg.state_dim), jnp.float32)
    pb, pc, mu, ls = actor_forward(cfg, p, s)
    assert pb.shape == (2, 6)
    np.testing.assert_allclose(pb.sum(-1), 1.0, rtol=1e-5)
