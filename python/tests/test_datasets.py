"""Synthetic dataset: determinism, learnability signal, shapes."""
import numpy as np

from compile import datasets


def test_shapes_and_dtypes():
    xtr, ytr, xte, yte = datasets.make_dataset(64, 32, seed=0)
    assert xtr.shape == (64, 3, 32, 32) and xtr.dtype == np.float32
    assert ytr.shape == (64,) and ytr.dtype == np.int32
    assert xte.shape == (32, 3, 32, 32)
    assert set(np.unique(ytr)).issubset(range(datasets.NUM_CLASSES))


def test_deterministic_in_seed():
    a = datasets.make_dataset(16, 8, seed=7)
    b = datasets.make_dataset(16, 8, seed=7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = datasets.make_dataset(16, 8, seed=8)
    assert not np.array_equal(a[0], c[0])


def test_classes_are_separable_by_template_correlation():
    """Nearest-template classification should beat chance by a wide margin —
    the learnability floor the backbones rely on."""
    xtr, ytr, xte, yte = datasets.make_dataset(256, 128, seed=1)
    # build per-class means from train
    means = np.stack(
        [xtr[ytr == k].mean(0) if (ytr == k).any() else np.zeros_like(xtr[0]) for k in range(datasets.NUM_CLASSES)]
    )
    flat_means = means.reshape(datasets.NUM_CLASSES, -1)
    flat_test = xte.reshape(len(xte), -1)
    pred = np.argmax(flat_test @ flat_means.T, axis=1)
    acc = (pred == yte).mean()
    assert acc > 0.5, acc  # chance = 1/16
