"""Device-profile model: structure, calibration anchors, JALAD entries."""
import pytest

from compile.profile import DeviceModel, build_profile


@pytest.mark.parametrize("model", ["resnet18", "vgg11", "mobilenetv2"])
def test_profile_structure(model):
    p = build_profile(model)
    assert p["n_partition_choices"] == 6
    assert len(p["entries"]) == 6
    # b = 0: no local compute, raw input payload
    e0 = p["entries"][0]
    assert e0["t_f"] == 0.0 and e0["bits"] == p["input_bits"]
    # b = 5: full local, no payload
    e5 = p["entries"][5]
    assert e5["bits"] == 0.0
    assert abs(e5["t_f"] - p["full_local"]["t"]) < 1e-9
    # cumulative latency is monotone across cuts
    t = [p["entries"][b]["t_f"] for b in range(1, 6)]
    assert all(a <= b + 1e-12 for a, b in zip(t, t[1:]))
    # payloads roughly non-increasing with depth (paper-geometry rates keep
    # them near-constant; integer channel rounding allows small upticks)
    bits = [p["entries"][b]["bits"] for b in range(1, 5)]
    assert all(later <= earlier * 1.5 for earlier, later in zip(bits, bits[1:]))


def test_resnet18_calibration_anchor():
    """T0 = 0.5 s is ~10x full-local latency; beta ~ latency/energy ~ 0.47."""
    p = build_profile("resnet18")
    t, e = p["full_local"]["t"], p["full_local"]["e"]
    assert 0.03 < t < 0.07, t          # ~50 ms
    assert 0.3 < t / e < 0.6, t / e    # beta anchor


def test_jalad_entries_heavier_than_ae():
    p = build_profile("resnet18")
    for je in p["jalad"]:
        ae = p["entries"][je["b"]]
        assert je["bits"] > ae["bits"], je
        assert je["t_c"] > ae["t_c"], je


def test_fig7_energy_observation():
    """Paper: overhead below full-local at every cut except energy at the
    last cut (which exceeds it)."""
    p = build_profile("resnet18")
    full_t, full_e = p["full_local"]["t"], p["full_local"]["e"]
    for b in range(1, 4):
        e = p["entries"][b]
        assert e["t_f"] + e["t_c"] < full_t
        assert e["e_f"] + e["e_c"] < full_e
    last = p["entries"][4]
    assert last["e_f"] + last["e_c"] > full_e * 0.99


def test_device_knobs_affect_costs():
    fast = DeviceModel(peak_flops=300e9)
    slow = DeviceModel(peak_flops=50e9)
    pf = build_profile("resnet18", device=fast)
    ps = build_profile("resnet18", device=slow)
    assert pf["full_local"]["t"] < ps["full_local"]["t"]


def test_chosen_rates_override():
    rates = [{"ch_r_paper": 4, "bits": 8}] * 4
    p = build_profile("resnet18", chosen_rates=rates)
    for b in range(1, 5):
        assert p["entries"][b]["feature"]["ch_r"] == 4
