"""Autoencoder compressor: rate math (Eq. 3), roundtrip, training signal."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.autoencoder import (
    AeConfig,
    ae_flatten,
    ae_init,
    ae_unflatten,
    decode,
    encode,
    reconstruct_ste,
)


def test_rate_formula_eq3():
    cfg = AeConfig(ch=64, ch_r=8, bits=8)
    assert cfg.rate == 64 * 32 / (8 * 8)  # = 32x
    assert AeConfig(ch=512, ch_r=512, bits=32).rate == 1.0


def test_compressed_bits_accounting():
    cfg = AeConfig(ch=64, ch_r=16, bits=8)
    assert cfg.compressed_bits(10, 10) == 16 * 100 * 8 + 64


def test_flatten_unflatten_roundtrip():
    cfg = AeConfig(ch=12, ch_r=3, bits=8)
    p = ae_init(cfg, 0)
    flat = ae_flatten(p)
    back = ae_unflatten(cfg, jnp.asarray(flat))
    for k in p:
        np.testing.assert_allclose(np.asarray(back[k]), p[k], atol=0)


def test_encode_decode_shapes_and_codes():
    cfg = AeConfig(ch=8, ch_r=2, bits=8)
    p = {k: jnp.asarray(v) for k, v in ae_init(cfg, 1).items()}
    feat = jnp.asarray(np.random.default_rng(0).normal(size=(1, 8, 4, 4)), jnp.float32)
    codes, lo, hi = encode(cfg, p, feat)
    assert codes.shape == (1, 2, 4, 4)
    c = np.asarray(codes)
    assert np.all(c == np.round(c)) and c.min() >= 0 and c.max() <= 255
    restored = decode(cfg, p, codes, lo, hi)
    assert restored.shape == feat.shape


def test_identityish_ae_reconstructs():
    """With ch_r = ch and identity-ish weights, reconstruction is near-exact
    (up to 8-bit quantization)."""
    cfg = AeConfig(ch=4, ch_r=4, bits=8)
    p = {
        "w_enc": jnp.eye(4),
        "b_enc": jnp.zeros(4),
        "w_dec": jnp.eye(4),
        "b_dec": jnp.zeros(4),
    }
    feat = jnp.asarray(np.random.default_rng(1).uniform(-1, 1, (1, 4, 6, 6)), jnp.float32)
    codes, lo, hi = encode(cfg, p, feat)
    restored = decode(cfg, p, codes, lo, hi)
    step = float(hi - lo) / 255
    assert float(jnp.max(jnp.abs(restored - feat))) <= step / 2 + 1e-5


def test_training_reduces_reconstruction_error():
    cfg = AeConfig(ch=16, ch_r=4, bits=8)
    params = {k: jnp.asarray(v) for k, v in ae_init(cfg, 2).items()}
    rng = np.random.default_rng(3)
    # low-rank features: 4 latent channels mixed into 16 -> perfectly
    # compressible at R_c = 4
    basis = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    lat = jnp.asarray(rng.normal(size=(8, 4, 8, 8)), jnp.float32)
    feat = jnp.einsum("nchw,ck->nkhw", lat, basis)

    def loss_fn(p):
        return jnp.mean((reconstruct_ste(cfg, p, feat) - feat) ** 2)

    loss0 = float(loss_fn(params))
    lr = 3e-2
    grad = jax.jit(jax.grad(loss_fn))
    for _ in range(60):
        g = grad(params)
        params = {k: params[k] - lr * g[k] for k in params}
    loss1 = float(loss_fn(params))
    assert loss1 < loss0 * 0.2, (loss0, loss1)
